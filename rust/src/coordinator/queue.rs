//! The keyed FIFO of Algorithm 1.
//!
//! A single FIFO holds `(request, granted_width)` entries; batches are
//! always formed from the *head's* key — the scheduler scans forward
//! collecting up to `B_max` requests whose key matches the head, leaving
//! everything else in order. `requeue_front` restores a batch when no
//! instance can serve it (Algorithm 1 line 9).

use std::collections::VecDeque;

use super::request::{BatchKey, Request};

/// One run of consecutive same-segment requests at the front of the
/// leader's global FIFO — the unit `Router::plan` decides over (each run
/// yields one `HeadView`, and a decision's micro-batch group is drawn
/// from its run).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HeadRun {
    /// FIFO index of the run's first request.
    pub start: usize,
    /// Consecutive same-segment requests in the run.
    pub len: usize,
    /// Segment every member of the run needs.
    pub seg: usize,
}

/// Scan the global FIFO front for up to `max_runs` segment runs, each
/// counted up to `run_cap` entries. A run normally ends where the next
/// request needs a different segment; a run that reaches `run_cap` ends
/// the whole scan (its overflow — and any runs behind it — simply wait
/// for the next planning event, which a deep backlog needs anyway).
/// The cap bounds the scan at `max_runs · run_cap` entries, so routing
/// a deep same-segment backlog stays linear instead of re-walking the
/// backlog on every planning event.
pub fn head_runs(
    fifo: &VecDeque<Request>,
    max_runs: usize,
    run_cap: usize,
) -> Vec<HeadRun> {
    let mut runs = Vec::new();
    head_runs_into(fifo, max_runs, run_cap, &mut runs);
    runs
}

/// Allocation-free [`head_runs`]: clears and fills `runs` in place, so a
/// caller driving a planning loop (the engine routes every shard on every
/// event) reuses one scratch buffer instead of allocating a `Vec` per
/// planning call (§Perf).
pub fn head_runs_into(
    fifo: &VecDeque<Request>,
    max_runs: usize,
    run_cap: usize,
    runs: &mut Vec<HeadRun>,
) {
    runs.clear();
    let run_cap = run_cap.max(1);
    for (i, req) in fifo.iter().enumerate() {
        match runs.last_mut() {
            Some(run) if run.seg == req.seg && run.len < run_cap => {
                run.len += 1;
            }
            // the current run hit the cap and continues in reality:
            // stop scanning — anything past it is unknowable without
            // walking the run to its true end
            Some(run) if run.seg == req.seg => break,
            _ => {
                if runs.len() == max_runs {
                    break;
                }
                runs.push(HeadRun { start: i, len: 1, seg: req.seg });
            }
        }
    }
}

/// Queue entry: a request plus the width the router granted it.
#[derive(Clone, Copy, Debug)]
pub struct Queued {
    pub req: Request,
    pub width: f64,
}

impl Queued {
    pub fn key(&self) -> BatchKey {
        self.req.key_with(self.width)
    }
}

/// FIFO with batch-by-head-key extraction.
#[derive(Clone, Debug, Default)]
pub struct KeyedFifo {
    items: VecDeque<Queued>,
}

impl KeyedFifo {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn push_back(&mut self, q: Queued) {
        self.items.push_back(q);
    }

    /// Key of the FIFO head (Algorithm 1 line 3: "peek head key").
    pub fn head_key(&self) -> Option<BatchKey> {
        self.items.front().map(Queued::key)
    }

    /// Pop up to `b_max` entries matching the head's key, preserving the
    /// relative order of everything else.
    ///
    /// Fast path (§Perf): blocks are usually enqueued contiguously, so
    /// when the matching entries form a prefix (followed by no further
    /// matches, or the batch is already full) we `drain` the prefix
    /// instead of rebuilding the queue.
    pub fn pop_batch(&mut self, b_max: usize) -> Vec<Queued> {
        let Some(key) = self.head_key() else {
            return Vec::new();
        };
        // length of the matching contiguous prefix (≤ b_max)
        let mut prefix = 0usize;
        for q in self.items.iter() {
            if prefix < b_max && q.key() == key {
                prefix += 1;
            } else {
                break;
            }
        }
        let full = prefix == b_max;
        let more_matches_later =
            !full && self.items.iter().skip(prefix).any(|q| q.key() == key);
        if full || !more_matches_later {
            return self.items.drain(..prefix).collect();
        }
        // slow path: matches are scattered — rebuild preserving order
        let mut batch = Vec::new();
        let mut rest = VecDeque::with_capacity(self.items.len());
        while let Some(q) = self.items.pop_front() {
            if batch.len() < b_max && q.key() == key {
                batch.push(q);
            } else {
                rest.push_back(q);
            }
        }
        self.items = rest;
        batch
    }

    /// Take every queued entry, in order (device-dropout re-routing).
    pub fn drain_all(&mut self) -> Vec<Queued> {
        self.items.drain(..).collect()
    }

    /// Put a batch back at the front (keeps batch order).
    pub fn requeue_front(&mut self, batch: Vec<Queued>) {
        for q in batch.into_iter().rev() {
            self.items.push_front(q);
        }
    }

    /// Queue length per segment (telemetry).
    pub fn len_by_segment(&self, num_segments: usize) -> Vec<usize> {
        let mut counts = vec![0usize; num_segments];
        for q in &self.items {
            if q.req.seg < num_segments {
                counts[q.req.seg] += 1;
            }
        }
        counts
    }

    /// Oldest enqueue timestamp (age-based overload detection).
    pub fn oldest_enqueue(&self) -> Option<f64> {
        self.items.front().map(|q| q.req.enqueued_at)
    }

    pub fn iter(&self) -> impl Iterator<Item = &Queued> {
        self.items.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utilx::Rng;

    fn q(id: u64, seg: usize, width: f64, w_prev: f64) -> Queued {
        let mut req = Request::new(id, id as f64, width);
        req.seg = seg;
        req.w_prev = w_prev;
        Queued { req, width }
    }

    #[test]
    fn batch_takes_only_head_key_up_to_bmax() {
        let mut fifo = KeyedFifo::new();
        fifo.push_back(q(0, 0, 0.5, 1.0));
        fifo.push_back(q(1, 1, 0.5, 0.5)); // different seg
        fifo.push_back(q(2, 0, 0.5, 1.0));
        fifo.push_back(q(3, 0, 0.25, 1.0)); // different width
        fifo.push_back(q(4, 0, 0.5, 1.0));

        let batch = fifo.pop_batch(10);
        assert_eq!(
            batch.iter().map(|x| x.req.id).collect::<Vec<_>>(),
            vec![0, 2, 4]
        );
        // remaining order preserved
        assert_eq!(
            fifo.iter().map(|x| x.req.id).collect::<Vec<_>>(),
            vec![1, 3]
        );
    }

    #[test]
    fn bmax_limits_batch() {
        let mut fifo = KeyedFifo::new();
        for i in 0..6 {
            fifo.push_back(q(i, 0, 1.0, 1.0));
        }
        let batch = fifo.pop_batch(4);
        assert_eq!(batch.len(), 4);
        assert_eq!(fifo.len(), 2);
        // next batch picks up the remainder in order
        let batch2 = fifo.pop_batch(4);
        assert_eq!(
            batch2.iter().map(|x| x.req.id).collect::<Vec<_>>(),
            vec![4, 5]
        );
    }

    #[test]
    fn requeue_front_restores_order() {
        let mut fifo = KeyedFifo::new();
        for i in 0..4 {
            fifo.push_back(q(i, 0, 1.0, 1.0));
        }
        let batch = fifo.pop_batch(2);
        fifo.requeue_front(batch);
        assert_eq!(
            fifo.iter().map(|x| x.req.id).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
    }

    #[test]
    fn empty_fifo_behaviour() {
        let mut fifo = KeyedFifo::new();
        assert!(fifo.head_key().is_none());
        assert!(fifo.pop_batch(8).is_empty());
        assert!(fifo.is_empty());
    }

    #[test]
    fn len_by_segment_counts() {
        let mut fifo = KeyedFifo::new();
        fifo.push_back(q(0, 0, 1.0, 1.0));
        fifo.push_back(q(1, 2, 1.0, 1.0));
        fifo.push_back(q(2, 2, 0.5, 1.0));
        assert_eq!(fifo.len_by_segment(4), vec![1, 0, 2, 0]);
    }

    fn fifo_of_segs(segs: &[usize]) -> VecDeque<Request> {
        segs.iter()
            .enumerate()
            .map(|(i, &seg)| {
                let mut r = Request::new(i as u64, 0.0, 1.0);
                r.seg = seg;
                r
            })
            .collect()
    }

    #[test]
    fn head_runs_splits_on_segment_boundaries() {
        let fifo = fifo_of_segs(&[0, 0, 1, 1, 1, 0, 2]);
        let runs = head_runs(&fifo, 8, 64);
        assert_eq!(
            runs,
            vec![
                HeadRun { start: 0, len: 2, seg: 0 },
                HeadRun { start: 2, len: 3, seg: 1 },
                HeadRun { start: 5, len: 1, seg: 0 },
                HeadRun { start: 6, len: 1, seg: 2 },
            ]
        );
    }

    #[test]
    fn head_runs_honors_the_window() {
        let fifo = fifo_of_segs(&[0, 1, 2, 3]);
        let runs = head_runs(&fifo, 2, 64);
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[1], HeadRun { start: 1, len: 1, seg: 1 });
        assert!(head_runs(&fifo, 1, 64).len() == 1);
        assert!(head_runs(&VecDeque::new(), 4, 64).is_empty());
    }

    #[test]
    fn head_runs_caps_deep_runs_and_stops_the_scan() {
        // a deep same-segment backlog: the scan is bounded by the cap
        // and runs behind the capped run wait for the next event
        let mut segs = vec![0usize; 10];
        segs.extend_from_slice(&[1, 1]);
        let fifo = fifo_of_segs(&segs);
        let runs = head_runs(&fifo, 4, 3);
        assert_eq!(runs, vec![HeadRun { start: 0, len: 3, seg: 0 }]);
        // a run that ends naturally at exactly the cap doesn't block
        // the next run from being reported
        let fifo = fifo_of_segs(&[0, 0, 0, 1, 1]);
        let runs = head_runs(&fifo, 4, 3);
        assert_eq!(
            runs,
            vec![
                HeadRun { start: 0, len: 3, seg: 0 },
                HeadRun { start: 3, len: 2, seg: 1 },
            ]
        );
        // degenerate cap floors at 1
        let fifo = fifo_of_segs(&[0, 0]);
        assert_eq!(head_runs(&fifo, 4, 0), vec![HeadRun { start: 0, len: 1, seg: 0 }]);
    }

    #[test]
    fn head_runs_empty_fifo_yields_no_runs() {
        let empty: VecDeque<Request> = VecDeque::new();
        assert!(head_runs(&empty, 1, 1).is_empty());
        assert!(head_runs(&empty, 8, 64).is_empty());
        assert!(head_runs(&empty, usize::MAX, usize::MAX).is_empty());
    }

    #[test]
    fn head_runs_run_exactly_at_cap_with_nothing_behind() {
        // a run whose natural end coincides with the cap must be
        // reported whole, and an exactly-cap-length FIFO must not scan
        // past its end
        let cap = 5usize;
        let fifo = fifo_of_segs(&[0; 5]);
        let runs = head_runs(&fifo, 4, cap);
        assert_eq!(runs, vec![HeadRun { start: 0, len: 5, seg: 0 }]);
        // one more same-segment entry: the capped run now truncates and
        // ends the scan (the overflow waits for the next planning event)
        let fifo = fifo_of_segs(&[0; 6]);
        let runs = head_runs(&fifo, 4, cap);
        assert_eq!(runs, vec![HeadRun { start: 0, len: 5, seg: 0 }]);
        // a different segment right at the cap boundary starts a new run
        let fifo = fifo_of_segs(&[0, 0, 0, 0, 0, 1]);
        let runs = head_runs(&fifo, 4, cap);
        assert_eq!(
            runs,
            vec![
                HeadRun { start: 0, len: 5, seg: 0 },
                HeadRun { start: 5, len: 1, seg: 1 },
            ]
        );
    }

    #[test]
    fn head_runs_interleaved_segments_one_run_each() {
        // fully interleaved segments degenerate to length-1 runs, one
        // per window slot, offsets exact
        let fifo = fifo_of_segs(&[0, 1, 0, 1, 2, 3]);
        let runs = head_runs(&fifo, 4, 64);
        assert_eq!(
            runs,
            vec![
                HeadRun { start: 0, len: 1, seg: 0 },
                HeadRun { start: 1, len: 1, seg: 1 },
                HeadRun { start: 2, len: 1, seg: 0 },
                HeadRun { start: 3, len: 1, seg: 1 },
            ]
        );
        // widening the window exposes the tail runs too
        let runs = head_runs(&fifo, 8, 64);
        assert_eq!(runs.len(), 6);
        assert_eq!(runs[5], HeadRun { start: 5, len: 1, seg: 3 });
    }

    #[test]
    fn property_pop_batch_is_conservative() {
        // pop_batch + remainder always partitions the original multiset,
        // batch is key-homogeneous and starts with the old head.
        crate::utilx::prop::check("fifo-partition", 50, |rng: &mut Rng| {
            let mut fifo = KeyedFifo::new();
            let n = rng.index(30) + 1;
            let mut ids = Vec::new();
            for i in 0..n {
                let seg = rng.index(4);
                let w = [0.25, 0.5, 0.75, 1.0][rng.index(4)];
                let wp = [0.25, 0.5, 0.75, 1.0][rng.index(4)];
                fifo.push_back(q(i as u64, seg, w, wp));
                ids.push(i as u64);
            }
            let head = fifo.head_key().unwrap();
            let b_max = rng.index(8) + 1;
            let batch = fifo.pop_batch(b_max);
            if batch.is_empty() {
                return Err("batch must be non-empty when fifo non-empty".into());
            }
            if batch[0].req.id != ids[0] {
                return Err("head must open the batch".into());
            }
            if !batch.iter().all(|x| x.key() == head) {
                return Err("batch not key-homogeneous".into());
            }
            if batch.len() > b_max {
                return Err("batch exceeds b_max".into());
            }
            let mut seen: Vec<u64> = batch.iter().map(|x| x.req.id).collect();
            seen.extend(fifo.iter().map(|x| x.req.id));
            seen.sort_unstable();
            if seen != ids {
                return Err("requests lost or duplicated".into());
            }
            Ok(())
        });
    }
}
