//! The paper's system contribution: hierarchical scheduling for slimmable
//! multi-server inference.
//!
//! * [`request`] — request/block types keyed by `(segment, width, w_prev)`
//!   exactly as Algorithm 1's queue entries.
//! * [`queue`] — the keyed FIFO: batches are formed from the head's key.
//! * [`instance`] — loaded model instances (segment, width, busy, t_last)
//!   with best-fit lookup.
//! * [`greedy`] — Algorithm 1: best-fit dispatch, CANLOAD-guarded
//!   opportunistic scale-up, idle offload.
//! * [`router`] — the global dispatch layer behind the windowed
//!   `Router::plan` API: Random (Table III baseline), RoundRobin /
//!   LeastLoaded (algorithmic comparators), Edf (deadline-aware
//!   slack-ordered comparator), and the PPO router (Tables IV–V) with
//!   its batched inference path.
//! * [`admission`] — deficit-round-robin admission control ahead of
//!   routing: per-tenant credit queues with a burstiness cap, bounded
//!   scan/batch per tick, finite queues as backpressure, and a
//!   width-degradation overload policy.
//! * [`shard`] — multi-leader sharding of the global FIFO: leader
//!   shards with router replicas, deterministic request→shard
//!   assignment (`ShardAssign`), cross-shard rebalancing, and the
//!   `sharded_engine` constructor.
//! * [`telemetry`] — eq. 1's state vector + run-wide sampling.
//! * [`core`] — the reusable discrete-event substrate: deterministic
//!   event heap, block ledger, run metrics, and the [`core::DeviceModel`]
//!   / [`core::LocalScheduler`] attachment traits.
//! * [`engine`] — the discrete-event multi-server loop binding workload,
//!   router, per-server schedulers and devices; produces the Tables
//!   III–V metrics.

pub mod admission;
pub mod core;
pub mod engine;
pub mod greedy;
pub mod instance;
pub mod queue;
pub mod request;
pub mod router;
pub mod shard;
pub mod telemetry;

pub use admission::DrrGate;
pub use self::core::{
    BlockLedger, DeviceModel, EventQueue, HeapEventQueue, LocalScheduler, RunMetrics,
};
pub use engine::{Engine, RunOutcome};
pub use greedy::GreedyScheduler;
pub use instance::{Instance, InstancePool};
pub use queue::{head_runs, head_runs_into, HeadRun, KeyedFifo};
pub use request::{wkey, BatchKey, Request};
pub use router::{
    AlgoRouter, Decision, EdfRouter, HeadView, PlanError, Router, RouterSpec,
    RoutingPlan,
};
pub use shard::{
    sharded_engine, HashAssign, KeyAffineAssign, Migration, RoundRobinAssign,
    ShardAssign, ShardStats, ShardedEngine,
};
pub use telemetry::TelemetrySnapshot;
