//! Type-level shim of the `xla` (xla-rs) PJRT surface used by
//! `slim_scheduler::runtime`. The offline build environment has no XLA
//! shared library, so this crate keeps the runtime module compiling and
//! fails loudly at *runtime* if real PJRT execution is requested. All
//! runtime tests gate on `artifacts_available(..)`, which is false until
//! `make artifacts` runs, so `cargo test` passes without ever hitting
//! these paths. Replace this path dependency with the real `xla` crate to
//! serve compiled HLO for real.

use std::fmt;

/// Error type standing in for `xla::Error`.
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Self {
        Error(format!(
            "{what}: slim_scheduler was built against the offline xla shim \
             (no PJRT runtime); link the real xla crate to execute artifacts"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host literal (shape + f32 payload).
#[derive(Clone, Debug, Default)]
pub struct Literal {
    pub data: Vec<f32>,
    pub dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from an f32 slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: data.to_vec() }
    }

    /// Reshape in place (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Unwrap a 1-tuple result (identity in the shim).
    pub fn to_tuple1(self) -> Result<Literal> {
        Ok(self)
    }

    /// Copy out as a typed vec (f32 only in the shim).
    pub fn to_vec<T: From<f32>>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from(x)).collect())
    }
}

/// Parsed HLO module (never constructible without the real runtime).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!("parsing HLO text {path}")))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident result buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("materializing a PJRT buffer"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("executing a PJRT computation"))
    }
}

/// PJRT client handle. Construction succeeds (callers probe for missing
/// artifact files before ever compiling); parse/compile/execute fail.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compiling an XLA computation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims, vec![4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims, vec![2, 2]);
        assert!(l.reshape(&[3, 3]).is_err());
        let v: Vec<f32> = r.to_vec().unwrap();
        assert_eq!(v, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn runtime_entries_fail_loudly() {
        // client creation succeeds; actually touching the runtime fails
        let client = PjRtClient::cpu().expect("shim client");
        assert!(client.compile(&XlaComputation).is_err());
        let err = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("offline xla shim"), "{msg}");
        assert!(msg.contains("x.hlo.txt"), "{msg}");
    }
}
