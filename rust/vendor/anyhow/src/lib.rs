//! Minimal, dependency-free reimplementation of the `anyhow` API surface
//! this workspace uses: [`Error`], [`Result`], the [`anyhow!`], [`bail!`]
//! and [`ensure!`] macros, and the [`Context`] extension trait. The build
//! environment is fully offline, so vendoring the ~hundred lines we need
//! keeps `cargo build` hermetic while preserving source compatibility
//! with the real crate.

use std::fmt;

/// Boxed error with a human-readable chain, like `anyhow::Error`.
///
/// Deliberately does **not** implement `std::error::Error`, which is what
/// makes the blanket `From<E: std::error::Error>` impl coherent (same
/// trick as the real crate).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a context line, most-recent first (mirrors the `{:#}` /
    /// chain rendering of the real crate closely enough for logs).
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("...")` — format a new [`Error`].
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("...")` — early-return a formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "...")` — bail unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn from_std_error_and_display() {
        let e: Error = io_err().into();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_chains() {
        let r: Result<()> = Err(io_err()).context("reading manifest");
        let msg = r.unwrap_err().to_string();
        assert!(msg.starts_with("reading manifest"), "{msg}");
        assert!(msg.contains("gone"));
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<u32, std::io::Error> = Ok(7);
        let r = ok.with_context(|| panic!("must not evaluate"));
        assert_eq!(r.unwrap(), 7);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert!(none.context("missing").is_err());
    }

    #[test]
    fn macros() {
        fn inner(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert_eq!(inner(true).unwrap(), 1);
        assert!(inner(false).unwrap_err().to_string().contains("false"));
        let e = anyhow!("code {}", 42);
        assert_eq!(e.to_string(), "code 42");
    }
}
