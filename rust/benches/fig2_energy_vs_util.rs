//! Fig 2 regenerator — energy vs GPU utilization per width (RTX 2080 Ti).
//! Prints the series and checks the paper's shape: near-linear to the
//! ~90–95 % knee, sharply super-linear beyond it.

use slim_scheduler::benchx::{Bench, Table};
use slim_scheduler::experiments::{self, FIG23_UTILS};

fn main() {
    let rows = experiments::fig2_rows();
    let mut table = Table::new(
        "Fig 2 — energy (J) vs GPU utilization (RTX 2080 Ti)",
        &["util_pct", "w=0.25", "w=0.50", "w=0.75", "w=1.00"],
    );
    for row in &rows {
        table.rowf(row, 3);
    }
    table.print();

    // shape: monotone in util; post-knee slope >> pre-knee slope
    for col in 1..=4 {
        let e: Vec<f64> = rows.iter().map(|r| r[col]).collect();
        assert!(e.windows(2).all(|w| w[1] >= w[0]), "col {col}: {e:?}");
        // pre-knee slope between 30% and 70%
        let pre = (e[3] - e[1]) / (FIG23_UTILS[3] - FIG23_UTILS[1]);
        // post-knee slope between 93% and 99%
        let post = (e[8] - e[6]) / (FIG23_UTILS[8] - FIG23_UTILS[6]);
        assert!(
            post > 5.0 * pre,
            "col {col}: post-knee slope {post:.4} not >> pre {pre:.4}"
        );
    }
    // wider widths burn more energy at every utilization
    for row in &rows {
        assert!(row[1] < row[4], "{row:?}");
    }
    println!("shape checks OK: near-linear pre-knee, super-linear post-knee\n");

    let mut bench = Bench::from_env();
    bench.bench("fig2/full_series", || {
        std::hint::black_box(experiments::fig2_rows());
    });
    bench.emit_json("fig2_energy_vs_util");
}
