//! Table I regenerator — SlimResNet Top-1 under uniform width ratios.
//! The accuracy prior reproduces the published numbers exactly (they are
//! its calibration points); the bench also times the prior lookup, which
//! sits on the reward hot path.

use slim_scheduler::benchx::{Bench, Table};
use slim_scheduler::model::accuracy::UNIFORM_ACC;
use slim_scheduler::model::AccuracyPrior;

fn main() {
    let prior = AccuracyPrior::new();
    let mut table = Table::new(
        "Table I — Top-1 accuracy under uniform widths (CIFAR-100)",
        &["width", "paper_pct", "ours_pct"],
    );
    for &(w, paper) in &UNIFORM_ACC {
        let ours = prior.lookup(&[w, w, w, w]);
        table.rowf(&[w, paper, ours], 2);
        assert!((ours - paper).abs() < 1e-9, "w={w}: {ours} vs {paper}");
    }
    table.print();
    println!("exact match on all four uniform widths\n");

    let mut bench = Bench::from_env();
    let mut i = 0usize;
    bench.bench("accuracy_prior/uniform_lookup", || {
        let w = [0.25, 0.5, 0.75, 1.0][i % 4];
        i += 1;
        std::hint::black_box(prior.lookup(&[w, w, w, w]));
    });
    bench.emit_json("table1_accuracy");
}
