//! Table V regenerator — PPO+greedy under balanced weighting, measured
//! online (the paper attributes this row's variance to the scheduler's
//! live experimentation with slimming ratios). Shape targets: accuracy
//! above baseline, mean latency & energy below baseline, throughput below
//! baseline, latency spread of the same order as its mean.

use slim_scheduler::benchx::{Bench, Table};
use slim_scheduler::experiments;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_QUICK").is_ok();
    let (requests, episodes) = if quick { (2000, 5) } else { (6000, 10) };
    // BENCH_SCENARIO=<name> re-runs this table on any registered scenario
    let cfg = experiments::bench_cfg(requests, 42);
    let paper = cfg.scenario.as_deref().unwrap_or("paper") == "paper"
        && cfg.router.route_window == 1; // paper bands assume the per-head loop

    let mut bench = Bench::from_env();
    let mut results = None;
    bench.once(
        &format!("table5/train+eval_online({episodes} episodes x {requests} req)"),
        || {
            let baseline = experiments::run_random_baseline(&cfg);
            let (ppo, router) = experiments::run_table5(&cfg, episodes);
            results = Some((baseline, ppo, router));
        },
    );
    let (baseline, ppo, _router) = results.unwrap();

    let mut table = Table::new(
        "Table V — PPO+greedy (averaged/balanced, online): paper vs ours",
        &["metric", "paper_mean", "paper_std", "ours_mean", "ours_std"],
    );
    table.row(&["Accuracy (%)".into(), "75.26".into(), "".into(),
                format!("{:.2}", ppo.report.accuracy_pct), "".into()]);
    table.row(&["Latency (s)".into(), "6.100".into(), "11.673".into(),
                format!("{:.3}", ppo.report.latency.mean()),
                format!("{:.3}", ppo.report.latency.std())]);
    table.row(&["Energy (J)".into(), "1085.41".into(), "2125.62".into(),
                format!("{:.2}", ppo.report.energy.mean()),
                format!("{:.2}", ppo.report.energy.std())]);
    table.row(&["GPU Var".into(), "0.0815".into(), "0.0374".into(),
                format!("{:.4}", ppo.report.gpu_var.mean()),
                format!("{:.4}", ppo.report.gpu_var.std())]);
    table.print();
    println!(
        "baseline for reference: acc {:.2}%, latency {:.3}s, energy {:.1}J, thpt {:.1} img/s",
        baseline.report.accuracy_pct,
        baseline.report.latency.mean(),
        baseline.report.energy.mean(),
        baseline.report.throughput()
    );
    println!("ppo width histogram: {:?}", ppo.width_histogram);

    // shape assertions (Table V's trade-off signature — calibrated to
    // the paper cluster; other scenarios check completion + mixing only)
    if paper {
        assert!(
            ppo.report.accuracy_pct > baseline.report.accuracy_pct,
            "balanced policy must recover accuracy: {} vs {}",
            ppo.report.accuracy_pct,
            baseline.report.accuracy_pct
        );
        assert!(
            ppo.report.latency.mean() < baseline.report.latency.mean(),
            "mean latency must improve"
        );
        assert!(
            ppo.report.energy.mean() < baseline.report.energy.mean(),
            "mean energy must improve"
        );
        // high variance signature: spread comparable to the mean
        assert!(
            ppo.report.latency.std() > 0.5 * ppo.report.latency.mean(),
            "latency spread should stay large (live width experimentation): σ {} μ {}",
            ppo.report.latency.std(),
            ppo.report.latency.mean()
        );
        println!("shape checks OK: accuracy up, means down, spread stays wide\n");
    } else {
        println!(
            "scenario {:?}: completion + width-mixing checked, paper bands skipped\n",
            cfg.scenario.as_deref().unwrap_or("?")
        );
    }
    // width mixing, not collapse (holds on every scenario)
    let total = ppo.width_execs();
    let widest = ppo.width_histogram.iter().map(|&(_, c)| c).max().unwrap_or(0);
    let widest_frac = widest as f64 / total.max(1) as f64;
    assert!(widest_frac < 0.97, "policy collapsed: {:?}", ppo.width_histogram);
    bench.emit_json("table5_ppo_averaged");
}
