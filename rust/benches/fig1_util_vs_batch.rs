//! Fig 1 regenerator — GPU memory utilization vs batch size per width
//! (RTX 2080 Ti). Prints the series the paper plots and checks its two
//! shape properties: monotone growth in batch, earlier saturation (higher
//! footprint) at wider ratios. Also times the device-model evaluation.

use slim_scheduler::benchx::{Bench, Table};
use slim_scheduler::experiments;

fn main() {
    let rows = experiments::fig1_rows();
    let mut table = Table::new(
        "Fig 1 — GPU memory utilization (%) vs batch size (RTX 2080 Ti)",
        &["batch", "w=0.25", "w=0.50", "w=0.75", "w=1.00"],
    );
    for row in &rows {
        table.rowf(row, 2);
    }
    table.print();

    // shape checks (the paper's qualitative claims)
    for col in 1..=4 {
        let series: Vec<f64> = rows.iter().map(|r| r[col]).collect();
        assert!(
            series.windows(2).all(|w| w[1] >= w[0]),
            "col {col} not monotone in batch: {series:?}"
        );
    }
    for row in &rows {
        assert!(
            row[1] <= row[2] && row[2] <= row[3] && row[3] <= row[4],
            "wider must use >= memory: {row:?}"
        );
    }
    println!("shape checks OK: monotone in batch; wider saturates earlier\n");

    let mut bench = Bench::from_env();
    bench.bench("fig1/full_series", || {
        std::hint::black_box(experiments::fig1_rows());
    });
    bench.emit_json("fig1_util_vs_batch");
}
