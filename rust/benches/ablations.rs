//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!   A. ε-mixed server head ON vs OFF (exploration collapse risk)
//!   B. scale-up aggressiveness: N_new ∈ {1, 4}
//!   C. utilization block threshold: U_blk ∈ {50 %, 90 %, 101 %}
//!   D. reward-weight sweep α ∈ {0.02, 1, 3.5, 8} — traces the
//!      latency/accuracy trade-off surface between Tables IV and V.

use slim_scheduler::benchx::{Bench, Table};
use slim_scheduler::config::RewardCfg;
use slim_scheduler::coordinator::Engine;
use slim_scheduler::coordinator::router::RandomRouter;
use slim_scheduler::experiments;
use slim_scheduler::ppo::PpoRouter;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_QUICK").is_ok();
    let requests = if quick { 1500 } else { 4000 };
    let episodes = if quick { 4 } else { 6 };
    let mut bench = Bench::from_env();

    // ---- A: epsilon mixing on/off ----
    let mut table_a = Table::new(
        "Ablation A — ε-mixed server head (balanced reward)",
        &["eps", "accuracy", "lat_mean_s", "srv0_blocks", "srv1", "srv2"],
    );
    for &(label, eps_max, eps_min) in
        &[("on", 0.30f64, 0.02f64), ("off", 0.0, 0.0)]
    {
        let mut cfg = experiments::bench_cfg(requests, 42);
        cfg.ppo.eps_max = eps_max;
        cfg.ppo.eps_min = eps_min;
        let mut out = None;
        bench.once(&format!("ablation_a/eps_{label}"), || {
            out = Some(experiments::run_ppo_experiment(
                &cfg,
                RewardCfg::balanced(),
                episodes,
            ));
        });
        let (o, _r) = out.unwrap();
        let blocks: Vec<f64> = o
            .greedy_stats
            .iter()
            .map(|s| s.dispatches as f64)
            .collect();
        table_a.rowf(
            &[
                eps_max,
                o.report.accuracy_pct,
                o.report.latency.mean(),
                blocks[0],
                blocks[1],
                blocks[2],
            ],
            3,
        );
    }
    table_a.print();

    // ---- B: scale-up cap ----
    let mut table_b = Table::new(
        "Ablation B — scale-up cap N_new (random baseline)",
        &["n_new", "lat_mean_s", "lat_p99_s", "loads", "requeues"],
    );
    for &n_new in &[1usize, 4] {
        let mut cfg = experiments::bench_cfg(requests, 42);
        cfg.scheduler.n_new = n_new;
        let mut out = None;
        bench.once(&format!("ablation_b/n_new_{n_new}"), || {
            out = Some(experiments::run_random_baseline(&cfg));
        });
        let o = out.unwrap();
        let loads: u64 = o.greedy_stats.iter().map(|s| s.loads).sum();
        let requeues: u64 = o.greedy_stats.iter().map(|s| s.requeues).sum();
        table_b.rowf(
            &[
                n_new as f64,
                o.report.latency.mean(),
                o.report.latency.percentile(99.0),
                loads as f64,
                requeues as f64,
            ],
            3,
        );
    }
    table_b.print();

    // ---- C: utilization block threshold ----
    let mut table_c = Table::new(
        "Ablation C — CANLOAD utilization threshold U_blk",
        &["u_blk", "lat_mean_s", "util_blocked", "loads"],
    );
    for &u_blk in &[50.0f64, 90.0, 101.0] {
        let mut cfg = experiments::bench_cfg(requests, 42);
        cfg.scheduler.u_blk_pct = u_blk;
        let mut out = None;
        bench.once(&format!("ablation_c/u_blk_{u_blk}"), || {
            out = Some(experiments::run_random_baseline(&cfg));
        });
        let o = out.unwrap();
        let blocked: u64 = o.greedy_stats.iter().map(|s| s.blocked_by_util).sum();
        let loads: u64 = o.greedy_stats.iter().map(|s| s.loads).sum();
        table_c.rowf(
            &[u_blk, o.report.latency.mean(), blocked as f64, loads as f64],
            3,
        );
    }
    table_c.print();

    // ---- D: reward-weight trade-off surface ----
    let mut table_d = Table::new(
        "Ablation D — α sweep (accuracy weight): Table IV ⇄ Table V surface",
        &["alpha", "accuracy", "lat_mean_s", "energy_J", "slim_frac"],
    );
    for &alpha in &[0.02f64, 1.0, 3.5, 8.0] {
        let cfg = experiments::bench_cfg(requests, 42);
        let mut reward = RewardCfg::balanced();
        reward.alpha = alpha;
        if alpha < 0.1 {
            reward = RewardCfg::overfit();
        }
        let mut out = None;
        bench.once(&format!("ablation_d/alpha_{alpha}"), || {
            out = Some(experiments::run_ppo_experiment_online(&cfg, reward, episodes));
        });
        let (o, _r) = out.unwrap();
        let slim_frac = o.width_frac_at_most(0.5);
        table_d.rowf(
            &[
                alpha,
                o.report.accuracy_pct,
                o.report.latency.mean(),
                o.report.energy.mean(),
                slim_frac,
            ],
            3,
        );
    }
    table_d.print();

    // sanity: PPO decision cost is independent of ablation settings
    let mut r = PpoRouter::new(
        3,
        vec![0.25, 0.5, 0.75, 1.0],
        experiments::paper_cluster_cfg(10, 1).ppo,
        3,
    );
    r.eval_mode();
    let _ = Engine::new(
        experiments::paper_cluster_cfg(50, 1),
        RandomRouter::new(vec![0.25, 0.5, 0.75, 1.0], true, 8),
    )
    .run();
    bench.emit_json("ablations");
}
