//! Table IV regenerator — PPO+greedy under heavy latency/energy weighting
//! (the "overfit" policy). The paper's headline: −96.45 % mean latency,
//! −97.31 % energy vs the baseline, accuracy pinned to the slimmest
//! model's 70.30 %, throughput above baseline. We check each direction
//! and magnitude band (our substrate is a simulator — shape, not
//! absolute).

use slim_scheduler::benchx::{Bench, Table};
use slim_scheduler::experiments;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_QUICK").is_ok();
    let (requests, episodes) = if quick { (2000, 5) } else { (6000, 10) };
    // BENCH_SCENARIO / BENCH_WORKERS re-run this table per scenario and
    // with parallel rollout collection
    let cfg = experiments::bench_cfg(requests, 42);
    let workers = experiments::bench_workers();
    let paper = cfg.scenario.as_deref().unwrap_or("paper") == "paper"
        && cfg.router.route_window == 1; // paper bands assume the per-head loop

    let mut bench = Bench::from_env();
    let mut results = None;
    bench.once(
        &format!("table4/train+eval({episodes} episodes x {requests} req, {workers} workers)"),
        || {
            let baseline = experiments::run_random_baseline(&cfg);
            let (ppo, router) = experiments::run_ppo_experiment_workers(
                &cfg,
                slim_scheduler::config::RewardCfg::overfit(),
                episodes,
                workers,
            );
            results = Some((baseline, ppo, router));
        },
    );
    let (baseline, ppo, router) = results.unwrap();

    let lat_delta = experiments::pct_change(
        baseline.report.latency.mean(),
        ppo.report.latency.mean(),
    );
    let energy_delta = experiments::pct_change(
        baseline.report.energy.mean(),
        ppo.report.energy.mean(),
    );

    let mut table = Table::new(
        "Table IV — PPO+greedy (overfit): paper vs ours",
        &["metric", "paper", "ours"],
    );
    table.row(&["Accuracy (%)".into(), "70.30".into(),
                format!("{:.2}", ppo.report.accuracy_pct)]);
    table.row(&["Latency mean (s)".into(), "0.318e-3*".into(),
                format!("{:.4}", ppo.report.latency.mean())]);
    table.row(&["Energy mean (J)".into(), "52.85".into(),
                format!("{:.2}", ppo.report.energy.mean())]);
    table.row(&["Δlatency vs baseline".into(), "-96.45%".into(),
                format!("{lat_delta:.2}%")]);
    table.row(&["Δenergy vs baseline".into(), "-97.31%".into(),
                format!("{energy_delta:.2}%")]);
    table.row(&["Throughput vs baseline".into(), "+67.6%".into(),
                format!("{:+.1}%", experiments::pct_change(
                    baseline.report.throughput(), ppo.report.throughput()))]);
    table.print();
    println!("* the paper's Table IV mixes ms/s units; deltas are the comparable quantity\n");
    println!("width histogram: {:?}", ppo.width_histogram);
    println!("ppo updates: {}", router.stats.updates);

    // shape assertions (magnitude bands are calibrated to the paper
    // cluster with sequential online training; scenario / parallel runs
    // keep the direction checks only)
    if paper && workers <= 1 {
        assert!((ppo.report.accuracy_pct - 70.30).abs() < 0.8,
                "accuracy should pin to slimmest: {}", ppo.report.accuracy_pct);
        assert!(lat_delta < -90.0, "latency delta {lat_delta}%");
        assert!(energy_delta < -90.0, "energy delta {energy_delta}%");
        assert!(ppo.report.throughput() > baseline.report.throughput());
        assert!(ppo.width_frac_at_most(0.25) > 0.8,
                "policy must collapse onto 0.25×: {:?}", ppo.width_histogram);
        println!("shape checks OK: collapse to slimmest, >90% latency & energy cuts\n");
    } else {
        assert!(lat_delta < 0.0, "overfit policy must cut latency: {lat_delta}%");
        println!("scenario/parallel run: direction checks only\n");
    }
    bench.emit_json("table4_ppo_overfit");
}
