//! Micro-benchmarks of the L3 hot path — the quantities the §Perf pass
//! optimizes. Covers: keyed-FIFO batch formation, greedy scheduling sweep,
//! router decisions (random vs PPO inference, per-head vs batched plan),
//! policy forward/backward, device-model step, telemetry snapshot/state-
//! vector, calendar-queue vs binary-heap event churn, multi-leader shard
//! scaling on the `sharded-hot` scenario (BENCH_LEADERS accepts a comma
//! list, e.g. `4,16`), and (when artifacts are present) the real PJRT
//! segment execution. Emits the batched-vs-per-head PPO evaluation
//! speedup, the `leaders<N>_speedup_x` shard-scaling ratios, the
//! event-core `events_per_sec` / `wheel_vs_heap_speedup_x` pair, and the
//! observability-collector cost (`obs_overhead_pct`, instrumented vs
//! uninstrumented engine run) and the control-plane tax
//! (`ctrl_overhead_pct`, backlog controller vs none on a quiet run) as
//! derived metrics in `BENCH_micro_hotpath.json`.

use slim_scheduler::benchx::Bench;
use slim_scheduler::config::{Config, PpoCfg, SchedulerCfg};
use slim_scheduler::coordinator::queue::{KeyedFifo, Queued};
use slim_scheduler::coordinator::router::{
    HeadView, LeastLoadedRouter, RandomRouter, Router,
};
use slim_scheduler::coordinator::telemetry::{ServerTelemetry, TelemetrySnapshot};
use slim_scheduler::coordinator::{sharded_engine, Engine, GreedyScheduler, Request};
use slim_scheduler::model::ModelMeta;
use slim_scheduler::ppo::PpoRouter;
use slim_scheduler::runtime::artifact::artifacts_available;
use slim_scheduler::runtime::{HostTensor, SegmentExecutor};
use slim_scheduler::sim::{profiles, SimDevice};
use slim_scheduler::utilx::Rng;

fn queued(id: u64, seg: usize, width: f64) -> Queued {
    let mut req = Request::new(id, 0.0, width);
    req.seg = seg;
    Queued { req, width }
}

fn snapshot(n: usize) -> TelemetrySnapshot {
    TelemetrySnapshot {
        fifo_len: 12,
        done_count: 100,
        total_requests: 1000,
        servers: (0..n)
            .map(|i| ServerTelemetry {
                queue_len: i * 3,
                power_w: 120.0,
                util_pct: 25.0 * i as f64,
                mem_util: 0.3,
                instances: 2,
            })
            .collect(),
    }
}

fn main() {
    let mut bench = Bench::from_env();
    let mut rng = Rng::new(1);

    // ---- keyed FIFO ----
    bench.bench("fifo/push_pop_batch_64", || {
        let mut fifo = KeyedFifo::new();
        for i in 0..64 {
            fifo.push_back(queued(i, (i % 4) as usize, 0.5));
        }
        while !fifo.is_empty() {
            std::hint::black_box(fifo.pop_batch(16));
        }
    });

    // ---- greedy scheduler sweep ----
    bench.bench("greedy/step_32_requests", || {
        let mut s = GreedyScheduler::new(SchedulerCfg::default(), ModelMeta::default());
        let mut dev = SimDevice::new(profiles::rtx2080ti());
        for i in 0..32 {
            s.enqueue(queued(i, (i % 4) as usize, 0.5));
        }
        std::hint::black_box(s.step(0.0, &mut dev));
    });

    // ---- routers ----
    let snap = snapshot(3);
    let head = HeadView::new(0.5, 0);
    let mut random = RandomRouter::new(vec![0.25, 0.5, 0.75, 1.0], true, 8);
    bench.bench("router/random_decision", || {
        std::hint::black_box(random.route_one(&snap, &head, &mut rng));
    });

    let mut ppo = PpoRouter::new(3, vec![0.25, 0.5, 0.75, 1.0], PpoCfg::default(), 7);
    ppo.eval_mode();
    bench.bench("router/ppo_decision(11->64->64->12 mlp)", || {
        std::hint::black_box(ppo.route_one(&snap, &head, &mut rng));
    });

    // windowed plan: 16 heads through one batched matrix forward
    let heads16: Vec<HeadView> = (0..16)
        .map(|i| HeadView {
            fifo_index: i,
            w_req: 0.5,
            seg: i % 4,
            age_s: 0.0,
            slack_s: 1.0,
        })
        .collect();
    bench.bench("router/ppo_plan_window16", || {
        std::hint::black_box(ppo.plan(&snap, &heads16, &mut rng));
    });

    // ---- per-head vs batched PPO evaluation (the plan-API payoff) ----
    let batch_n = 16usize;
    let base_state = snap.to_state_vector();
    let dim = base_state.len();
    let mut states = Vec::with_capacity(batch_n * dim);
    for k in 0..batch_n {
        let mut s = base_state.clone();
        s[0] = ((batch_n - k) as f64 / 64.0).min(4.0); // queue position
        states.extend_from_slice(&s);
    }
    let eps = vec![0.0; batch_n];
    let mut scratch_a = (Vec::new(), Vec::new());
    let mut scratch_b = (Vec::new(), Vec::new());
    let per_head_name = "policy/sample_x16_per_head";
    bench.bench(per_head_name, || {
        for k in 0..batch_n {
            std::hint::black_box(ppo.policy.sample_notrain(
                &states[k * dim..(k + 1) * dim],
                0.0,
                &mut rng,
                &mut scratch_a,
            ));
        }
    });
    let batched_name = "policy/sample_batch16(one matrix fwd)";
    bench.bench(batched_name, || {
        std::hint::black_box(ppo.policy.sample_batch(
            &states,
            batch_n,
            &eps,
            &mut rng,
            &mut scratch_b,
        ));
    });
    if let (Some(per_head), Some(batched)) = (
        bench.mean_ns_of(per_head_name),
        bench.mean_ns_of(batched_name),
    ) {
        // >1 means the batched path wins; tracked in the perf trajectory
        bench.metric("ppo_batch16_speedup_x", per_head / batched);
    }

    // ---- policy forward+backward ----
    let train_ppo =
        PpoRouter::new(3, vec![0.25, 0.5, 0.75, 1.0], PpoCfg::default(), 8);
    let state = snap.to_state_vector();
    bench.bench("policy/evaluate", || {
        std::hint::black_box(train_ppo.policy.evaluate(&state, None, 0.1));
    });
    let (eval, _) = train_ppo.policy.evaluate(&state, None, 0.1);
    let action = slim_scheduler::ppo::ActionTriple { srv: 1, w: 2, g: 0 };
    bench.bench("policy/backward_transition", || {
        let mut grads = train_ppo.policy.mlp.zeros_like();
        train_ppo
            .policy
            .backward_transition(&eval, action, 0.1, -0.5, 0.01, 0.2, &mut grads);
        std::hint::black_box(grads);
    });

    // ---- device model ----
    bench.bench("device/begin_finish_batch", || {
        let mut d = SimDevice::new(profiles::rtx2080ti());
        let (id, f) = d.begin_batch(0.0, 1_000_000_000, 10_000_000, 8, 0.5);
        d.finish_batch(f, id);
        std::hint::black_box(d.energy_j());
    });

    // ---- telemetry ----
    bench.bench("telemetry/state_vector", || {
        std::hint::black_box(snap.to_state_vector());
    });

    // ---- end-to-end small sim ----
    bench.bench("engine/300_request_run", || {
        let mut cfg = Config::default();
        cfg.workload.total_requests = 300;
        cfg.workload.rate_hz = 200.0;
        let router = RandomRouter::new(cfg.scheduler.widths.clone(), true, 8);
        std::hint::black_box(Engine::new(cfg, router).run());
    });

    // ---- observability overhead: instrumented vs uninstrumented ----
    // The same 300-request run with the collector on (counters, stage
    // histograms, tick series — the default) and off. The budget is
    // <= 5% overhead; the derived `obs_overhead_pct` metric tracks it
    // in the perf trajectory (CI checks presence, acceptance the bar).
    let obs_run = |enabled: bool| {
        let mut cfg = Config::default();
        cfg.workload.total_requests = 300;
        cfg.workload.rate_hz = 200.0;
        cfg.obs.enabled = enabled;
        let router = RandomRouter::new(cfg.scheduler.widths.clone(), true, 8);
        Engine::new(cfg, router).run()
    };
    let obs_on_name = "engine/300_request_run_obs_on";
    bench.bench(obs_on_name, || {
        std::hint::black_box(obs_run(true));
    });
    let obs_off_name = "engine/300_request_run_obs_off";
    bench.bench(obs_off_name, || {
        std::hint::black_box(obs_run(false));
    });
    if let (Some(on_ns), Some(off_ns)) = (
        bench.mean_ns_of(obs_on_name),
        bench.mean_ns_of(obs_off_name),
    ) {
        bench.metric("obs_overhead_pct", (on_ns / off_ns - 1.0) * 100.0);
    }

    // ---- control-plane overhead: controller on vs off ----
    // The same 300-request run with the backlog controller wired into
    // the telemetry tick and without one. The quiet run never crosses
    // the hysteresis high water, so this measures the pure control-plane
    // tax: one tick-row build plus one knob diff per telemetry tick.
    // Budget <= 5%, same bar as the collector (`ctrl_overhead_pct`).
    let ctrl_run = |kind: slim_scheduler::config::ControllerKind| {
        let mut cfg = Config::default();
        cfg.workload.total_requests = 300;
        cfg.workload.rate_hz = 200.0;
        cfg.ctrl.controller = kind;
        let router = RandomRouter::new(cfg.scheduler.widths.clone(), true, 8);
        Engine::new(cfg, router).run()
    };
    let ctrl_on_name = "engine/300_request_run_ctrl_backlog";
    bench.bench(ctrl_on_name, || {
        std::hint::black_box(ctrl_run(
            slim_scheduler::config::ControllerKind::Backlog,
        ));
    });
    let ctrl_off_name = "engine/300_request_run_ctrl_none";
    bench.bench(ctrl_off_name, || {
        std::hint::black_box(ctrl_run(slim_scheduler::config::ControllerKind::None));
    });
    if let (Some(on_ns), Some(off_ns)) = (
        bench.mean_ns_of(ctrl_on_name),
        bench.mean_ns_of(ctrl_off_name),
    ) {
        bench.metric("ctrl_overhead_pct", (on_ns / off_ns - 1.0) * 100.0);
    }

    // ---- event-queue churn: calendar queue vs binary heap ----
    // Steady-state hold-and-churn at ~4096 pending events, the regime a
    // million-request run lives in: every iteration pops the earliest
    // event and schedules a successor a short random offset ahead, so
    // both queues stay at constant occupancy while time advances. The
    // identical offset stream (same seed) feeds both structures.
    let held = 4096usize;
    let churn_offsets = |rng: &mut Rng| rng.below(1000) as f64 * 1e-3 + 1e-4;
    let mut cal: slim_scheduler::coordinator::EventQueue<u32> =
        slim_scheduler::coordinator::EventQueue::new();
    let mut cal_rng = Rng::new(97);
    for i in 0..held {
        let dt = churn_offsets(&mut cal_rng);
        cal.push(dt, i as u32);
    }
    let cal_name = "events/calendar_pop_push_held4096";
    bench.bench(cal_name, || {
        let (t, ev) = cal.pop().expect("queue never drains");
        cal.push(t + churn_offsets(&mut cal_rng), ev);
        std::hint::black_box(t);
    });
    let mut heap: slim_scheduler::coordinator::HeapEventQueue<u32> =
        slim_scheduler::coordinator::HeapEventQueue::new();
    let mut heap_rng = Rng::new(97);
    for i in 0..held {
        let dt = churn_offsets(&mut heap_rng);
        heap.push(dt, i as u32);
    }
    let heap_name = "events/heap_pop_push_held4096";
    bench.bench(heap_name, || {
        let (t, ev) = heap.pop().expect("queue never drains");
        heap.push(t + churn_offsets(&mut heap_rng), ev);
        std::hint::black_box(t);
    });
    if let (Some(cal_ns), Some(heap_ns)) =
        (bench.mean_ns_of(cal_name), bench.mean_ns_of(heap_name))
    {
        // one iteration = one pop + one push, i.e. one event through
        // the queue; >1 speedup means the calendar queue wins at this
        // occupancy (CI checks presence, acceptance checks >= 1.0)
        bench.metric("events_per_sec", 1e9 / cal_ns);
        bench.metric("wheel_vs_heap_speedup_x", heap_ns / cal_ns);
    }

    // ---- shard scaling: single vs multi-leader coordinator ----
    // The sharded-hot scenario gives each leader finite routing capacity
    // (leader_service_s), so one leader saturates below the offered load
    // while BENCH_LEADERS (default 4) shards drain at arrival pace. The
    // scaling win is the ratio of simulated drain times — measured, not
    // asserted. The metric name carries the actual shard count
    // (`leaders<N>_speedup_x`), so trajectories from different
    // BENCH_LEADERS settings can never be mistaken for one another; the
    // default (and the CI setting) is 4, i.e. `leaders4_speedup_x`.
    let leaders_list: Vec<usize> = match std::env::var("BENCH_LEADERS") {
        Ok(v) if !v.is_empty() => v
            .split(',')
            .map(|s| {
                s.trim()
                    .parse()
                    .unwrap_or_else(|e| panic!("BENCH_LEADERS '{s}': {e}"))
            })
            .collect(),
        _ => vec![4],
    };
    let leaders_list: Vec<usize> =
        leaders_list.into_iter().filter(|&n| n >= 2).collect();
    if leaders_list.is_empty() {
        eprintln!("shard scaling skipped: BENCH_LEADERS has nothing to compare");
    } else {
        let shard_requests = if bench.quick() { 800 } else { 2000 };
        let mut hot = Config::default();
        slim_scheduler::sim::scenarios::apply_named("sharded-hot", &mut hot)
            .expect("sharded-hot registered");
        hot.workload.total_requests = shard_requests;
        hot.seed = 42;
        let run_hot = |n_leaders: usize| {
            let mut cfg = hot.clone();
            cfg.shard.leaders = n_leaders;
            let router =
                LeastLoadedRouter::new(cfg.scheduler.widths.clone(), 16);
            sharded_engine(cfg, router).run()
        };
        // the single-leader baseline is shared by every entry in the list
        let mut dur_1 = 0.0f64;
        bench.once(
            &format!("shard/sharded_hot_{shard_requests}req_1leader"),
            || {
                let out = run_hot(1);
                assert_eq!(out.report.completed, shard_requests as u64);
                dur_1 = out.sim_duration_s;
            },
        );
        let mut clamps_reported = false;
        for &leaders in &leaders_list {
            let mut dur_n = 0.0f64;
            let mut clamps = 0u64;
            bench.once(
                &format!("shard/sharded_hot_{shard_requests}req_{leaders}leaders"),
                || {
                    let out = run_hot(leaders);
                    assert_eq!(out.report.completed, shard_requests as u64);
                    dur_n = out.sim_duration_s;
                    clamps = out.plan_clamps;
                },
            );
            if dur_1 > 0.0 && dur_n > 0.0 {
                // >1 means the sharded leader tier drains the same
                // workload faster in virtual time (CI checks presence and
                // the acceptance bar checks > 1.0 on sharded-hot)
                bench.metric(&format!("leaders{leaders}_speedup_x"), dur_1 / dur_n);
                if !clamps_reported {
                    bench.metric("sharded_hot_plan_clamps", clamps as f64);
                    clamps_reported = true;
                }
            }
        }
    }

    // ---- real PJRT execution (skipped when artifacts missing) ----
    if artifacts_available("artifacts") {
        let mut ex = SegmentExecutor::new("artifacts").expect("executor");
        ex.warm_all(&[0.25, 1.0]).expect("warm");
        let meta = ModelMeta::default();
        let (in_shape, _) = meta.seg_io_shapes(0, 4);
        let x = HostTensor::from_vec(
            &in_shape,
            (0..in_shape.iter().product::<usize>())
                .map(|i| ((i % 13) as f32 - 6.0) / 6.0)
                .collect(),
        );
        bench.bench("pjrt/seg0_b4_w025", || {
            std::hint::black_box(ex.execute(0, 0.25, &x).expect("exec"));
        });
        bench.bench("pjrt/seg0_b4_w100", || {
            std::hint::black_box(ex.execute(0, 1.0, &x).expect("exec"));
        });
        bench.bench("pjrt/full_forward_b4_w025", || {
            std::hint::black_box(
                ex.full_forward(&[0.25, 0.25, 0.25, 0.25], &x).expect("fwd"),
            );
        });
    } else {
        eprintln!("pjrt benches skipped: run `make artifacts` first");
    }
    bench.emit_json("micro_hotpath");
}
