//! Fig 3 regenerator — batch latency vs GPU utilization per width
//! (RTX 2080 Ti), same knee-shape checks as Fig 2 on the latency axis.

use slim_scheduler::benchx::{Bench, Table};
use slim_scheduler::experiments::{self, FIG23_UTILS};

fn main() {
    let rows = experiments::fig3_rows();
    let mut table = Table::new(
        "Fig 3 — batch latency (s) vs GPU utilization (RTX 2080 Ti)",
        &["util_pct", "w=0.25", "w=0.50", "w=0.75", "w=1.00"],
    );
    for row in &rows {
        table.rowf(row, 4);
    }
    table.print();

    for col in 1..=4 {
        let l: Vec<f64> = rows.iter().map(|r| r[col]).collect();
        assert!(l.windows(2).all(|w| w[1] >= w[0]), "col {col}: {l:?}");
        let pre = (l[3] - l[1]) / (FIG23_UTILS[3] - FIG23_UTILS[1]);
        let post = (l[8] - l[6]) / (FIG23_UTILS[8] - FIG23_UTILS[6]);
        assert!(
            post > 5.0 * pre,
            "col {col}: post-knee slope {post:.6} not >> pre {pre:.6}"
        );
    }
    // slimmer is faster at every utilization
    for row in &rows {
        assert!(row[1] < row[4], "{row:?}");
    }
    println!("shape checks OK: latency knee at ~90-95% utilization\n");

    let mut bench = Bench::from_env();
    bench.bench("fig3/full_series", || {
        std::hint::black_box(experiments::fig3_rows());
    });
    bench.emit_json("fig3_latency_vs_util");
}
