//! Table II regenerator — Top-1 under the paper's randomized mixed-width
//! tuples, plus the additive-model residuals on every published point and
//! the full 4^4 tuple surface timing.

use slim_scheduler::benchx::{Bench, Table};
use slim_scheduler::model::accuracy::MIXED_ACC;
use slim_scheduler::model::{AccuracyPrior, WIDTHS};

fn main() {
    let prior = AccuracyPrior::new();
    let mut table = Table::new(
        "Table II — Top-1 under randomized mixed widths (CIFAR-100)",
        &["w1", "w2", "w3", "w4", "paper_pct", "ours_pct"],
    );
    for &(tuple, paper) in &MIXED_ACC {
        let ours = prior.lookup(&tuple);
        table.rowf(&[tuple[0], tuple[1], tuple[2], tuple[3], paper, ours], 2);
        assert!((ours - paper).abs() < 1e-9);
    }
    table.print();

    // the Table II ordering property: later segments matter more
    let last_heavy = prior.lookup(&[0.25, 0.50, 0.75, 1.00]);
    let first_heavy = prior.lookup(&[1.00, 0.75, 0.50, 0.25]);
    assert!(last_heavy > first_heavy);
    println!(
        "ordering OK: widening later segments ({last_heavy:.2}%) beats \
         widening earlier ones ({first_heavy:.2}%)\n"
    );

    let mut bench = Bench::from_env();
    bench.bench("accuracy_prior/full_256_tuple_surface", || {
        let mut acc = 0.0;
        for &a in &WIDTHS {
            for &b in &WIDTHS {
                for &c in &WIDTHS {
                    for &d in &WIDTHS {
                        acc += prior.lookup(&[a, b, c, d]);
                    }
                }
            }
        }
        std::hint::black_box(acc);
    });
    bench.emit_json("table2_accuracy");
}
