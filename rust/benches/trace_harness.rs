//! End-to-end timing of the counterfactual router-evaluation harness:
//! train a small PPO checkpoint, record one trace, replay the
//! algorithmic field plus the `ppo:<checkpoint>` entrant over it, and
//! compute the paired significance block. Emits each candidate's paired
//! latency delta and sign-test p-value as derived metrics in
//! `BENCH_trace_harness.json`, so the perf trajectory records both how
//! long the harness takes and what it concluded.

use slim_scheduler::benchx::Bench;
use slim_scheduler::config::RewardCfg;
use slim_scheduler::experiments;
use slim_scheduler::trace::{compare_routers, record_trace};
use slim_scheduler::utilx::Json;

fn main() {
    let mut bench = Bench::from_env();
    let quick = bench.quick();
    let requests = if quick { 600 } else { 2500 };
    let episodes = if quick { 1 } else { 3 };
    let cfg = experiments::bench_cfg(requests, 42);

    // train + checkpoint through the same file path the CLI cycle uses,
    // so the bench exercises the `ppo:<path>` spelling end to end
    let mut ckpt_cfg = cfg.clone();
    ckpt_cfg.ppo.horizon = 128;
    let mut trained = None;
    bench.once("trace_harness/train_ppo", || {
        trained =
            Some(experiments::train_ppo(&ckpt_cfg, RewardCfg::overfit(), episodes));
    });
    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
    let ckpt_path = format!("{dir}/trace_harness_ppo.json");
    std::fs::write(&ckpt_path, trained.unwrap().to_json().to_string_pretty())
        .expect("checkpoint writes");

    let mut trace = None;
    bench.once("trace_harness/record_trace", || {
        trace = Some(record_trace(&cfg, "random").expect("recording succeeds"));
    });
    let trace = trace.unwrap();

    let names: Vec<String> = vec![
        "random".to_string(),
        "edf".to_string(),
        format!("ppo:{ckpt_path}"),
    ];
    let mut report = None;
    bench.once("trace_harness/compare_3way", || {
        report = Some(
            compare_routers(&cfg, &trace, &names).expect("comparison succeeds"),
        );
    });
    let report = report.unwrap();
    if let Some(pairs) = report.get("pairs").and_then(Json::as_arr) {
        for pair in pairs {
            let router = pair.get("router").and_then(Json::as_str).unwrap_or("?");
            let label = if router.starts_with("ppo:") { "ppo" } else { router };
            let f = |k: &str| {
                pair.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN)
            };
            bench.metric(
                &format!("{label}_latency_delta_mean_s"),
                f("latency_delta_mean_s"),
            );
            bench.metric(&format!("{label}_sign_test_p"), f("sign_test_p"));
        }
    }

    bench.emit_json("trace_harness");
}
