//! End-to-end timing of the counterfactual router-evaluation harness:
//! train a small PPO checkpoint, record one trace, replay the
//! algorithmic field plus the `ppo:<checkpoint>` entrant over it, and
//! compute the paired significance block. Emits each candidate's paired
//! latency delta and sign-test p-value as derived metrics in
//! `BENCH_trace_harness.json`, so the perf trajectory records both how
//! long the harness takes and what it concluded.

use slim_scheduler::benchx::Bench;
use slim_scheduler::config::RewardCfg;
use slim_scheduler::experiments;
use slim_scheduler::trace::{
    compare_routers, compare_routers_opts, record_trace, CompareOpts,
};
use slim_scheduler::utilx::Json;

fn main() {
    let mut bench = Bench::from_env();
    let quick = bench.quick();
    let requests = if quick { 600 } else { 2500 };
    let episodes = if quick { 1 } else { 3 };
    let cfg = experiments::bench_cfg(requests, 42);

    // train + checkpoint through the same file path the CLI cycle uses,
    // so the bench exercises the `ppo:<path>` spelling end to end
    let mut ckpt_cfg = cfg.clone();
    ckpt_cfg.ppo.horizon = 128;
    let mut trained = None;
    bench.once("trace_harness/train_ppo", || {
        trained =
            Some(experiments::train_ppo(&ckpt_cfg, RewardCfg::overfit(), episodes));
    });
    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
    let ckpt_path = format!("{dir}/trace_harness_ppo.json");
    std::fs::write(&ckpt_path, trained.unwrap().to_json().to_string_pretty())
        .expect("checkpoint writes");

    let mut trace = None;
    bench.once("trace_harness/record_trace", || {
        trace = Some(record_trace(&cfg, "random").expect("recording succeeds"));
    });
    let trace = trace.unwrap();

    let names: Vec<String> = vec![
        "random".to_string(),
        "edf".to_string(),
        format!("ppo:{ckpt_path}"),
    ];
    let mut report = None;
    bench.once("trace_harness/compare_3way", || {
        report = Some(
            compare_routers(&cfg, &trace, &names).expect("comparison succeeds"),
        );
    });
    let report = report.unwrap();
    if let Some(pairs) = report.get("pairs").and_then(Json::as_arr) {
        for pair in pairs {
            let router = pair.get("router").and_then(Json::as_str).unwrap_or("?");
            let label = if router.starts_with("ppo:") { "ppo" } else { router };
            let f = |k: &str| {
                pair.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN)
            };
            bench.metric(
                &format!("{label}_latency_delta_mean_s"),
                f("latency_delta_mean_s"),
            );
            bench.metric(&format!("{label}_sign_test_p"), f("sign_test_p"));
        }
    }

    // ---- evaluation fan-out: threaded entrant replays ----------------
    // the same 5-entrant field replayed sequentially and at 4 eval
    // threads produces byte-identical reports, so the wall-clock ratio
    // is pure fan-out speedup
    let field5: Vec<String> = vec![
        "random".to_string(),
        "round-robin".to_string(),
        "least-loaded".to_string(),
        "edf".to_string(),
        format!("ppo:{ckpt_path}"),
    ];
    let lean = CompareOpts { per_request: false, ..CompareOpts::default() };
    bench.once("trace_harness/compare_5way_threads1", || {
        compare_routers_opts(&cfg, &trace, &field5, lean)
            .expect("sequential 5-way comparison succeeds");
    });
    bench.once("trace_harness/compare_5way_threads4", || {
        compare_routers_opts(
            &cfg,
            &trace,
            &field5,
            CompareOpts { eval_threads: 4, ..lean },
        )
        .expect("threaded 5-way comparison succeeds");
    });
    if let (Some(t1), Some(t4)) = (
        bench.mean_ns_of("trace_harness/compare_5way_threads1"),
        bench.mean_ns_of("trace_harness/compare_5way_threads4"),
    ) {
        bench.metric("eval_fanout_speedup_x", t1 / t4);
    }

    // ---- scenario-parallel trace-study -------------------------------
    let study_requests = if quick { 120 } else { 400 };
    let study_field: Vec<String> =
        vec!["random".to_string(), "edf".to_string()];
    bench.once("trace_harness/study_threads1", || {
        experiments::trace_study(
            &ckpt_path,
            &study_field,
            study_requests,
            42,
            1,
            false,
        )
        .expect("sequential study succeeds");
    });
    bench.once("trace_harness/study_threads4", || {
        experiments::trace_study(
            &ckpt_path,
            &study_field,
            study_requests,
            42,
            4,
            false,
        )
        .expect("threaded study succeeds");
    });
    if let (Some(t1), Some(t4)) = (
        bench.mean_ns_of("trace_harness/study_threads1"),
        bench.mean_ns_of("trace_harness/study_threads4"),
    ) {
        bench.metric("study_fanout_speedup_x", t1 / t4);
    }

    bench.emit_json("trace_harness");
}
