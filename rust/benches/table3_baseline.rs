//! Table III regenerator — baseline scheduler (greedy executors, uniform
//! random routing and random width selection) on the simulated 3-GPU
//! cluster. Prints the paper's table layout plus our measured row and
//! checks the baseline's qualitative signature: saturated cluster, high
//! mean latency/energy, mid-range accuracy. Also runs the deadline-aware
//! EDF comparator on the same configuration (an extra "ours" row beyond
//! the paper) and surfaces both runs' plan-clamp counts in the bench
//! JSON, so silently-repaired routers are visible in the trajectory.

use slim_scheduler::benchx::{Bench, Table};
use slim_scheduler::coordinator::router::EdfRouter;
use slim_scheduler::coordinator::sharded_engine;
use slim_scheduler::experiments;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_QUICK").is_ok();
    let requests = if quick { 2000 } else { 8000 };
    // BENCH_SCENARIO=<name> re-runs this table on any registered scenario
    let cfg = experiments::bench_cfg(requests, 42);
    let paper = cfg.scenario.as_deref().unwrap_or("paper") == "paper"
        && cfg.router.route_window == 1; // paper bands assume the per-head loop

    let mut bench = Bench::from_env();
    let mut outcome = None;
    bench.once(&format!("table3/baseline_run({requests} req)"), || {
        outcome = Some(experiments::run_random_baseline(&cfg));
    });
    let out = outcome.unwrap();

    // deadline-aware comparator on the identical configuration: EDF
    // orders each routing window by SLA slack and gives the latest head
    // the emptiest server (stays None when BENCH_FILTER skips it)
    let mut edf_outcome = None;
    bench.once(&format!("table3/edf_run({requests} req)"), || {
        let router = EdfRouter::new(cfg.scheduler.widths.clone(), 16);
        edf_outcome = Some(sharded_engine(cfg.clone(), router).run());
    });

    let mut table = Table::new(
        "Table III — baseline scheduler (3-GPU cluster): paper vs ours",
        &["metric", "paper_mean", "paper_std", "ours_mean", "ours_std"],
    );
    table.row(&[
        "Accuracy (%)".into(),
        "74.43".into(),
        "".into(),
        format!("{:.2}", out.report.accuracy_pct),
        "".into(),
    ]);
    table.row(&[
        "Latency (s)".into(),
        "8.979".into(),
        "7.302".into(),
        format!("{:.3}", out.report.latency.mean()),
        format!("{:.3}", out.report.latency.std()),
    ]);
    table.row(&[
        "Energy (J)".into(),
        "1967.94".into(),
        "1629.53".into(),
        format!("{:.2}", out.report.energy.mean()),
        format!("{:.2}", out.report.energy.std()),
    ]);
    table.row(&[
        "GPU Var".into(),
        "0.0433".into(),
        "0.0216".into(),
        format!("{:.4}", out.report.gpu_var.mean()),
        format!("{:.4}", out.report.gpu_var.std()),
    ]);
    table.row(&[
        "Throughput (img/s)".into(),
        "-".into(),
        "".into(),
        format!("{:.1}", out.report.throughput()),
        "".into(),
    ]);
    table.print();

    if let Some(edf) = &edf_outcome {
        let mut edf_table = Table::new(
            "Table III+ — deadline-aware EDF comparator (same cluster, ours only)",
            &["metric", "random", "edf"],
        );
        edf_table.row(&[
            "Accuracy (%)".into(),
            format!("{:.2}", out.report.accuracy_pct),
            format!("{:.2}", edf.report.accuracy_pct),
        ]);
        edf_table.row(&[
            "Latency (s)".into(),
            format!("{:.3}", out.report.latency.mean()),
            format!("{:.3}", edf.report.latency.mean()),
        ]);
        edf_table.row(&[
            "e2e p99 (s)".into(),
            format!("{:.3}", out.e2e_latency.percentile(99.0)),
            format!("{:.3}", edf.e2e_latency.percentile(99.0)),
        ]);
        edf_table.row(&[
            "Energy (J)".into(),
            format!("{:.2}", out.report.energy.mean()),
            format!("{:.2}", edf.report.energy.mean()),
        ]);
        edf_table.row(&[
            "SLA miss rate".into(),
            format!("{:.4}", out.sla_miss_rate()),
            format!("{:.4}", edf.sla_miss_rate()),
        ]);
        edf_table.print();
        assert_eq!(edf.report.completed, requests as u64);

        // clamp counts ride into the bench JSON: a non-zero value means a
        // router emitted out-of-range fields that were silently repaired
        bench.metric("baseline_plan_clamps", out.plan_clamps as f64);
        bench.metric("edf_plan_clamps", edf.plan_clamps as f64);
        bench.metric("edf_e2e_p99_s", edf.e2e_latency.percentile(99.0));
        // SLA-miss rates (completions past --sla, default 1 s) — the
        // deadline counterpart of the latency row, per router
        bench.metric("baseline_sla_miss_rate", out.sla_miss_rate());
        bench.metric("edf_sla_miss_rate", edf.sla_miss_rate());
    }

    // qualitative signature (the saturation band is calibrated to the
    // paper cluster; other scenarios only check completion)
    assert_eq!(out.report.completed, requests as u64);
    if paper {
        assert!(out.report.accuracy_pct > 72.0 && out.report.accuracy_pct < 76.0,
                "accuracy {}", out.report.accuracy_pct);
        assert!(out.report.latency.mean() > 0.5,
                "baseline must be saturated: {}", out.report.latency.mean());
        assert!(out.report.energy.mean() > 100.0);
        println!("baseline signature OK: saturated, mid-accuracy, costly\n");
    } else {
        println!("scenario {:?}: completion checked, paper bands skipped\n",
                 cfg.scenario.as_deref().unwrap_or("?"));
    }
    bench.emit_json("table3_baseline");
}
