//! Table III regenerator — baseline scheduler (greedy executors, uniform
//! random routing and random width selection) on the simulated 3-GPU
//! cluster. Prints the paper's table layout plus our measured row and
//! checks the baseline's qualitative signature: saturated cluster, high
//! mean latency/energy, mid-range accuracy.

use slim_scheduler::benchx::{Bench, Table};
use slim_scheduler::experiments;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_QUICK").is_ok();
    let requests = if quick { 2000 } else { 8000 };
    // BENCH_SCENARIO=<name> re-runs this table on any registered scenario
    let cfg = experiments::bench_cfg(requests, 42);
    let paper = cfg.scenario.as_deref().unwrap_or("paper") == "paper"
        && cfg.router.route_window == 1; // paper bands assume the per-head loop

    let mut bench = Bench::from_env();
    let mut outcome = None;
    bench.once(&format!("table3/baseline_run({requests} req)"), || {
        outcome = Some(experiments::run_random_baseline(&cfg));
    });
    let out = outcome.unwrap();

    let mut table = Table::new(
        "Table III — baseline scheduler (3-GPU cluster): paper vs ours",
        &["metric", "paper_mean", "paper_std", "ours_mean", "ours_std"],
    );
    table.row(&[
        "Accuracy (%)".into(),
        "74.43".into(),
        "".into(),
        format!("{:.2}", out.report.accuracy_pct),
        "".into(),
    ]);
    table.row(&[
        "Latency (s)".into(),
        "8.979".into(),
        "7.302".into(),
        format!("{:.3}", out.report.latency.mean()),
        format!("{:.3}", out.report.latency.std()),
    ]);
    table.row(&[
        "Energy (J)".into(),
        "1967.94".into(),
        "1629.53".into(),
        format!("{:.2}", out.report.energy.mean()),
        format!("{:.2}", out.report.energy.std()),
    ]);
    table.row(&[
        "GPU Var".into(),
        "0.0433".into(),
        "0.0216".into(),
        format!("{:.4}", out.report.gpu_var.mean()),
        format!("{:.4}", out.report.gpu_var.std()),
    ]);
    table.row(&[
        "Throughput (img/s)".into(),
        "-".into(),
        "".into(),
        format!("{:.1}", out.report.throughput()),
        "".into(),
    ]);
    table.print();

    // qualitative signature (the saturation band is calibrated to the
    // paper cluster; other scenarios only check completion)
    assert_eq!(out.report.completed, requests as u64);
    if paper {
        assert!(out.report.accuracy_pct > 72.0 && out.report.accuracy_pct < 76.0,
                "accuracy {}", out.report.accuracy_pct);
        assert!(out.report.latency.mean() > 0.5,
                "baseline must be saturated: {}", out.report.latency.mean());
        assert!(out.report.energy.mean() > 100.0);
        println!("baseline signature OK: saturated, mid-accuracy, costly\n");
    } else {
        println!("scenario {:?}: completion checked, paper bands skipped\n",
                 cfg.scenario.as_deref().unwrap_or("?"));
    }
    bench.emit_json("table3_baseline");
}
