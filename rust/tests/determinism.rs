//! Seed-determinism regression tests.
//!
//! The whole experimental protocol (Tables III–V, the PPO training loop,
//! the scenario sweeps) assumes a run is a pure function of its
//! `Config.seed`. The load-bearing piece is the event queue's
//! (timestamp, sequence) tie-breaking in `coordinator::core::EventQueue`
//! (a calendar queue since the §Perf pass — `HeapEventQueue` keeps the
//! reference semantics) — if two same-timestamp events ever popped in a
//! structure-dependent order, RNG consumption would diverge and every
//! downstream number would wobble. These tests pin that guarantee across
//! the engine refactor, the scenario registry, both trainers, and the
//! `--plan-threads` parallel planner.

use slim_scheduler::config::{Config, RewardCfg};
use slim_scheduler::coordinator::router::RandomRouter;
use slim_scheduler::coordinator::telemetry::ServerTelemetry;
use slim_scheduler::coordinator::{sharded_engine, RunOutcome, TelemetrySnapshot};
use slim_scheduler::experiments;
use slim_scheduler::ppo::PpoRouter;
use slim_scheduler::sim::scenarios;

fn quick_cfg(seed: u64) -> Config {
    let mut cfg = experiments::paper_cluster_cfg(800, seed);
    cfg.ppo.horizon = 64;
    cfg
}

/// Outcomes must match bit-for-bit on every reported metric.
fn assert_identical(a: &RunOutcome, b: &RunOutcome) {
    assert_eq!(a.report.completed, b.report.completed);
    assert_eq!(a.blocks_completed, b.blocks_completed);
    assert_eq!(a.width_histogram, b.width_histogram);
    assert_eq!(a.report.accuracy_pct.to_bits(), b.report.accuracy_pct.to_bits());
    assert_eq!(
        a.report.latency.mean().to_bits(),
        b.report.latency.mean().to_bits()
    );
    assert_eq!(
        a.report.latency.std().to_bits(),
        b.report.latency.std().to_bits()
    );
    assert_eq!(
        a.report.energy.mean().to_bits(),
        b.report.energy.mean().to_bits()
    );
    assert_eq!(a.e2e_latency.mean().to_bits(), b.e2e_latency.mean().to_bits());
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    assert_eq!(a.sim_duration_s.to_bits(), b.sim_duration_s.to_bits());
    assert_eq!(a.telemetry.samples, b.telemetry.samples);
}

#[test]
fn random_baseline_is_a_pure_function_of_the_seed() {
    let a = experiments::run_random_baseline(&quick_cfg(42));
    let b = experiments::run_random_baseline(&quick_cfg(42));
    assert_identical(&a, &b);
}

#[test]
fn different_seeds_actually_differ() {
    let a = experiments::run_random_baseline(&quick_cfg(42));
    let b = experiments::run_random_baseline(&quick_cfg(43));
    // same workload size, different arrival/jitter draws
    assert_eq!(a.report.completed, b.report.completed);
    assert_ne!(
        a.report.latency.mean().to_bits(),
        b.report.latency.mean().to_bits()
    );
}

#[test]
fn every_scenario_baseline_is_deterministic() {
    for s in scenarios::all() {
        let run = || {
            let mut cfg = s.config();
            cfg.workload.total_requests = 300;
            cfg.seed = 7;
            experiments::run_random_baseline(&cfg)
        };
        let a = run();
        let b = run();
        // flash-crowd runs a DRR gate with a tight queue cap: shed
        // requests are deliberate backpressure, not lost work
        assert_eq!(a.report.completed + a.shed, 300, "{}", s.name);
        assert_eq!(a.shed, b.shed, "{}", s.name);
        assert_identical(&a, &b);
    }
}

fn probe() -> TelemetrySnapshot {
    TelemetrySnapshot {
        fifo_len: 9,
        done_count: 100,
        total_requests: 800,
        servers: (0..3)
            .map(|i| ServerTelemetry {
                queue_len: 3 * i,
                power_w: 120.0,
                util_pct: 30.0 * i as f64,
                mem_util: 0.3,
                instances: 1,
            })
            .collect(),
    }
}

fn fingerprint(router: &PpoRouter) -> Vec<u64> {
    let state = probe().to_state_vector();
    let (eval, _) = router.policy.evaluate(&state, None, 0.0);
    eval.p_srv
        .iter()
        .chain(&eval.p_w)
        .chain(&eval.p_g)
        .chain(std::iter::once(&eval.value))
        .map(|x| x.to_bits())
        .collect()
}

#[test]
fn sequential_ppo_training_is_deterministic_at_workers_1() {
    let cfg = quick_cfg(42);
    let a = experiments::train_ppo_workers(&cfg, RewardCfg::balanced(), 2, 1);
    let b = experiments::train_ppo_workers(&cfg, RewardCfg::balanced(), 2, 1);
    assert!(a.stats.updates > 0);
    assert_eq!(a.stats.updates, b.stats.updates);
    assert_eq!(a.stats.decisions, b.stats.decisions);
    assert_eq!(fingerprint(&a), fingerprint(&b));
}

#[test]
fn parallel_ppo_training_is_deterministic_per_seed_and_worker_count() {
    let cfg = quick_cfg(42);
    let a = experiments::train_ppo_workers(&cfg, RewardCfg::overfit(), 4, 2);
    let b = experiments::train_ppo_workers(&cfg, RewardCfg::overfit(), 4, 2);
    assert!(a.stats.updates > 0);
    assert_eq!(a.stats.decisions, b.stats.decisions);
    assert_eq!(fingerprint(&a), fingerprint(&b));
}

#[test]
fn parallel_workers_cover_the_same_episode_seeds_as_sequential() {
    // both trainers must draw worker-engine seeds from the same formula,
    // so scenario comparisons across --workers settings stay meaningful
    for ep in 0..6 {
        assert_eq!(
            slim_scheduler::ppo::parallel::episode_seed(42, ep),
            42u64.wrapping_add(1 + ep as u64 * 7919)
        );
    }
}

#[test]
fn windowed_baseline_is_a_pure_function_of_the_seed() {
    // the batched plan path must be as deterministic as the per-head one
    for window in [4usize, 16] {
        let run = || {
            let mut cfg = quick_cfg(42);
            cfg.router.route_window = window;
            experiments::run_random_baseline(&cfg)
        };
        let a = run();
        let b = run();
        assert_eq!(a.report.completed, 800, "window={window}");
        assert_identical(&a, &b);
    }
}

#[test]
fn windowed_ppo_training_is_deterministic_across_worker_counts() {
    // batched PPO inference (route_window > 1) must stay a pure function
    // of (seed, episodes, workers) for every worker count
    for workers in [1usize, 2] {
        let run = || {
            let mut cfg = quick_cfg(42);
            cfg.router.route_window = 4;
            experiments::train_ppo_workers(&cfg, RewardCfg::overfit(), 2, workers)
        };
        let a = run();
        let b = run();
        assert!(a.stats.decisions > 0, "workers={workers}");
        assert_eq!(a.stats.decisions, b.stats.decisions, "workers={workers}");
        assert_eq!(a.stats.updates, b.stats.updates, "workers={workers}");
        assert_eq!(fingerprint(&a), fingerprint(&b), "workers={workers}");
    }
}

/// A multi-leader run with finite routing capacity, the regime where
/// the parallel planner (`--plan-threads`) actually fans plan calls out
/// across threads.
fn sharded_run(seed: u64, leaders: usize, plan_threads: usize) -> RunOutcome {
    let mut cfg = quick_cfg(seed);
    cfg.workload.total_requests = 400;
    cfg.shard.leaders = leaders;
    cfg.shard.leader_service_s = 2e-4;
    cfg.shard.plan_threads = plan_threads;
    let router = RandomRouter::new(cfg.scheduler.widths.clone(), true, 8);
    sharded_engine(cfg, router).run()
}

#[test]
fn parallel_planner_is_a_pure_function_of_the_seed() {
    // plans run on scoped threads, but per-shard RNG streams and
    // shard-order apply keep the whole run seed-deterministic
    let a = sharded_run(42, 3, 2);
    let b = sharded_run(42, 3, 2);
    assert_eq!(a.report.completed, 400);
    assert_identical(&a, &b);
}

#[test]
fn parallel_planner_is_independent_of_thread_count() {
    // shard si always plans on plan_rngs[si], so how shards are chunked
    // over threads cannot leak into the event stream: any N >= 2 must
    // produce bit-identical outcomes
    let base = sharded_run(42, 4, 2);
    for threads in [3usize, 8] {
        let other = sharded_run(42, 4, threads);
        assert_identical(&base, &other);
    }
}

#[test]
fn plan_threads_one_is_the_sequential_baseline_at_every_leader_count() {
    // the default never enters the parallel path — an explicit
    // `--plan-threads 1` must reproduce the untouched config's run
    // bit for bit, with one leader and with several
    for leaders in [1usize, 3] {
        let mut cfg = quick_cfg(42);
        cfg.workload.total_requests = 400;
        cfg.shard.leaders = leaders;
        cfg.shard.leader_service_s = 2e-4;
        let mk = |cfg: &Config| {
            let router = RandomRouter::new(cfg.scheduler.widths.clone(), true, 8);
            sharded_engine(cfg.clone(), router).run()
        };
        let a = mk(&cfg);
        cfg.shard.plan_threads = 1;
        let b = mk(&cfg);
        assert_eq!(a.report.completed, 400, "leaders={leaders}");
        assert_identical(&a, &b);
    }
}

#[test]
fn frozen_eval_after_training_is_deterministic() {
    let cfg = quick_cfg(11);
    let (a, _) = experiments::run_ppo_experiment_workers(
        &cfg,
        RewardCfg::overfit(),
        2,
        2,
    );
    let (b, _) = experiments::run_ppo_experiment_workers(
        &cfg,
        RewardCfg::overfit(),
        2,
        2,
    );
    assert_identical(&a, &b);
}
