//! Multi-leader sharding equivalence and determinism suite.
//!
//! The sharded coordinator's contract, in order of strictness:
//!
//! 1. `--leaders 1` (the default) is **bit-identical per seed** to the
//!    single-leader engine — for algorithmic routers, for the PPO router,
//!    and even when the PPO router is wrapped in the `SharedPpoRouter`
//!    handle the multi-leader path uses. (The pre-refactor per-head
//!    decision bodies themselves are pinned by `plan_equivalence.rs`;
//!    together the two suites anchor the whole chain.)
//! 2. `--leaders N` completes every request, conserves segment
//!    executions, and is a pure function of the seed.
//! 3. Cross-shard rebalancing migrates work under imbalance and never
//!    loses a request.
//! 4. A finite-capacity leader (`leader_service_s > 0`) is a real
//!    bottleneck at one shard and stops being one at four — the scaling
//!    claim the `micro_hotpath` bench measures as `leaders4_speedup_x`.

use slim_scheduler::config::{Config, RewardCfg, ShardAssignKind};
use slim_scheduler::coordinator::router::{
    EdfRouter, LeastLoadedRouter, RandomRouter, RoundRobinRouter,
};
use slim_scheduler::coordinator::{sharded_engine, Engine, RunOutcome, ShardedEngine};
use slim_scheduler::experiments;
use slim_scheduler::ppo::{PpoRouter, SharedPpoRouter};
use slim_scheduler::sim::scenarios;

fn small_cfg(seed: u64) -> Config {
    let mut cfg = Config::default();
    cfg.seed = seed;
    cfg.workload.total_requests = 400;
    cfg.workload.rate_hz = 250.0;
    cfg
}

/// Byte-equality over every reported metric.
fn assert_bit_identical(a: &RunOutcome, b: &RunOutcome) {
    assert_eq!(a.report.completed, b.report.completed);
    assert_eq!(a.blocks_completed, b.blocks_completed);
    assert_eq!(a.width_histogram, b.width_histogram);
    assert_eq!(a.report.accuracy_pct.to_bits(), b.report.accuracy_pct.to_bits());
    assert_eq!(
        a.report.latency.mean().to_bits(),
        b.report.latency.mean().to_bits()
    );
    assert_eq!(
        a.report.energy.mean().to_bits(),
        b.report.energy.mean().to_bits()
    );
    assert_eq!(a.e2e_latency.mean().to_bits(), b.e2e_latency.mean().to_bits());
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    assert_eq!(a.sim_duration_s.to_bits(), b.sim_duration_s.to_bits());
}

fn conserves(out: &RunOutcome, requests: u64) {
    assert_eq!(out.report.completed, requests);
    assert_eq!(out.e2e_latency.count(), requests);
    assert_eq!(
        out.width_execs(),
        4 * requests,
        "segment executions lost or duplicated"
    );
    let assigned: u64 = out.shard_stats.iter().map(|s| s.assigned).sum();
    // heads routed must cover all four segments of every request (strict
    // equality doesn't hold under dropout, where readmitted heads route
    // again under fresh tags)
    let routed: u64 = out.shard_stats.iter().map(|s| s.routed_heads).sum();
    assert!(routed >= 4 * requests, "routed heads lost: {routed}");
    assert!(assigned >= requests);
    let migrated_in: u64 = out.shard_stats.iter().map(|s| s.migrated_in).sum();
    let migrated_out: u64 = out.shard_stats.iter().map(|s| s.migrated_out).sum();
    assert_eq!(migrated_in, migrated_out, "rebalancer lost requests");
}

// ---------------------------------------------------------------------
// 1 · leaders = 1 bit-identity
// ---------------------------------------------------------------------

#[test]
fn one_leader_sharded_engine_matches_single_leader_engine() {
    for seed in [7u64, 42] {
        let cfg = small_cfg(seed);
        assert_eq!(cfg.shard.leaders, 1, "default must stay single-leader");
        let widths = cfg.scheduler.widths.clone();
        let direct =
            Engine::new(cfg.clone(), RandomRouter::new(widths.clone(), true, 8))
                .run();
        let engine: ShardedEngine<RandomRouter> =
            sharded_engine(cfg, RandomRouter::new(widths, true, 8));
        let sharded = engine.run();
        assert_bit_identical(&direct, &sharded);
        assert_eq!(sharded.shard_stats.len(), 1);
    }
}

#[test]
fn one_leader_shared_ppo_handle_is_transparent() {
    // wrapping the PPO router in the shard-sharing handle must not
    // change a single draw: the handle only adds an uncontended mutex
    let cfg = small_cfg(42);
    let widths = cfg.scheduler.widths.clone();
    let mk = || {
        PpoRouter::new(cfg.devices.len(), widths.clone(), cfg.ppo.clone(), cfg.seed)
    };
    let (direct, _) = Engine::new(cfg.clone(), mk()).run_returning_router();
    let (wrapped, handle) =
        Engine::new(cfg.clone(), SharedPpoRouter::new(mk())).run_returning_router();
    assert_bit_identical(&direct, &wrapped);
    let inner = handle.into_inner();
    assert!(inner.stats.decisions > 0);
}

#[test]
fn assignment_kind_is_irrelevant_at_one_leader() {
    let mut cfg = small_cfg(42);
    let widths = cfg.scheduler.widths.clone();
    let hash = sharded_engine(
        cfg.clone(),
        LeastLoadedRouter::new(widths.clone(), 16),
    )
    .run();
    cfg.shard.assign = ShardAssignKind::RoundRobin;
    let rr = sharded_engine(cfg, LeastLoadedRouter::new(widths, 16)).run();
    assert_bit_identical(&hash, &rr);
}

// ---------------------------------------------------------------------
// 2 · leaders = N completion, conservation, determinism
// ---------------------------------------------------------------------

#[test]
fn four_leaders_complete_and_conserve_for_every_router() {
    let mut cfg = small_cfg(42);
    cfg.shard.leaders = 4;
    let widths = cfg.scheduler.widths.clone();

    let out =
        sharded_engine(cfg.clone(), RandomRouter::new(widths.clone(), true, 8))
            .run();
    conserves(&out, 400);
    assert_eq!(out.shard_stats.len(), 4);
    // hash assignment actually spreads across the shards
    assert!(
        out.shard_stats.iter().filter(|s| s.assigned > 0).count() >= 3,
        "assignment herded: {:?}",
        out.shard_stats
    );

    let out = sharded_engine(
        cfg.clone(),
        RoundRobinRouter::new(widths.clone(), 8),
    )
    .run();
    conserves(&out, 400);

    let out = sharded_engine(
        cfg.clone(),
        LeastLoadedRouter::new(widths.clone(), 16),
    )
    .run();
    conserves(&out, 400);

    let out = sharded_engine(cfg.clone(), EdfRouter::new(widths, 16)).run();
    conserves(&out, 400);
}

#[test]
#[should_panic(expected = "at most 256 leader shards")]
fn more_than_256_leaders_is_rejected() {
    // the tag namespace reserves one byte for the shard index; beyond
    // that, ledger tags would silently collide — fail fast instead
    let mut cfg = small_cfg(42);
    cfg.shard.leaders = 300;
    let widths = cfg.scheduler.widths.clone();
    let _ = sharded_engine(cfg, RandomRouter::new(widths, true, 8));
}

#[test]
fn sharded_runs_are_pure_functions_of_the_seed() {
    for leaders in [2usize, 4] {
        for kind in [ShardAssignKind::Hash, ShardAssignKind::RoundRobin] {
            let run = || {
                let mut cfg = small_cfg(42);
                cfg.shard.leaders = leaders;
                cfg.shard.assign = kind;
                let widths = cfg.scheduler.widths.clone();
                sharded_engine(cfg, RandomRouter::new(widths, true, 8)).run()
            };
            let a = run();
            let b = run();
            assert_bit_identical(&a, &b);
            assert_eq!(a.shard_stats, b.shard_stats, "{leaders} {kind:?}");
        }
    }
}

#[test]
fn sharded_ppo_training_is_deterministic_across_worker_counts() {
    // request→shard assignment (and everything downstream) must be a
    // pure function of (seed, episodes, workers) even with the policy
    // shared across shards
    let probe_fingerprint = |router: &PpoRouter| -> Vec<u64> {
        let snap = slim_scheduler::coordinator::TelemetrySnapshot {
            fifo_len: 9,
            done_count: 100,
            total_requests: 800,
            servers: (0..3).map(|_| Default::default()).collect(),
        };
        let state = snap.to_state_vector();
        let (eval, _) = router.policy.evaluate(&state, None, 0.0);
        eval.p_srv
            .iter()
            .chain(&eval.p_w)
            .chain(&eval.p_g)
            .map(|x| x.to_bits())
            .collect()
    };
    for workers in [1usize, 2] {
        let run = || {
            let mut cfg = small_cfg(42);
            cfg.shard.leaders = 2;
            cfg.ppo.horizon = 64;
            experiments::train_ppo_workers(&cfg, RewardCfg::overfit(), 2, workers)
        };
        let a = run();
        let b = run();
        assert!(a.stats.decisions > 0, "workers={workers}");
        assert_eq!(a.stats.decisions, b.stats.decisions, "workers={workers}");
        assert_eq!(a.stats.updates, b.stats.updates, "workers={workers}");
        assert_eq!(
            probe_fingerprint(&a),
            probe_fingerprint(&b),
            "workers={workers}"
        );
    }
}

#[test]
fn dropout_still_completes_under_sharding() {
    let mut cfg = small_cfg(42);
    cfg.workload.total_requests = 250;
    cfg.workload.rate_hz = 150.0;
    cfg.shard.leaders = 3;
    cfg.dropout = Some(slim_scheduler::config::DropoutCfg { server: 0, at_s: 0.3 });
    let widths = cfg.scheduler.widths.clone();
    let out = sharded_engine(cfg, RandomRouter::new(widths, true, 4)).run();
    conserves(&out, 250);
}

// ---------------------------------------------------------------------
// 3 · rebalancing under a hot, finite-capacity leader tier
// ---------------------------------------------------------------------

fn hot_cfg(requests: usize) -> Config {
    let mut cfg = Config::default();
    scenarios::apply_named("sharded-hot", &mut cfg).expect("registered");
    cfg.workload.total_requests = requests;
    cfg.seed = 42;
    cfg
}

#[test]
fn rebalancer_migrates_work_between_hot_leaders() {
    let mut cfg = hot_cfg(600);
    cfg.shard.leaders = 4;
    // slow leaders + hair-trigger threshold: backlog and imbalance are
    // guaranteed, and every migration must conserve requests
    cfg.shard.leader_service_s = 0.003;
    cfg.shard.rebalance_threshold = 2;
    let widths = cfg.scheduler.widths.clone();
    let out = sharded_engine(cfg, LeastLoadedRouter::new(widths, 16)).run();
    conserves(&out, 600);
    let migrated: u64 = out.shard_stats.iter().map(|s| s.migrated_in).sum();
    assert!(migrated > 0, "no migrations despite saturated leaders");
    // backlog genuinely accrued somewhere
    assert!(
        out.shard_stats.iter().any(|s| s.max_depth > 2),
        "leaders never backlogged: {:?}",
        out.shard_stats
    );
}

#[test]
fn rebalance_disabled_means_no_migrations() {
    let mut cfg = hot_cfg(300);
    cfg.shard.leaders = 4;
    cfg.shard.rebalance_threshold = 0;
    let widths = cfg.scheduler.widths.clone();
    let out = sharded_engine(cfg, LeastLoadedRouter::new(widths, 16)).run();
    conserves(&out, 300);
    assert!(out.shard_stats.iter().all(|s| s.migrated_in == 0));
    assert!(out.shard_stats.iter().all(|s| s.migrated_out == 0));
}

// ---------------------------------------------------------------------
// 4 · the scaling claim itself
// ---------------------------------------------------------------------

#[test]
fn finite_leader_capacity_bottlenecks_one_leader_not_four() {
    let run = |leaders: usize| {
        let mut cfg = hot_cfg(700);
        cfg.shard.leaders = leaders;
        let widths = cfg.scheduler.widths.clone();
        sharded_engine(cfg, LeastLoadedRouter::new(widths, 16)).run()
    };
    let one = run(1);
    let four = run(4);
    conserves(&one, 700);
    conserves(&four, 700);
    // one finite-capacity leader saturates below the offered load: the
    // sharded tier must drain the identical workload measurably faster
    // in virtual time (this is leaders4_speedup_x > 1.0, as a test)
    assert!(
        one.sim_duration_s > four.sim_duration_s * 1.1,
        "no scaling win: 1 leader {:.3}s vs 4 leaders {:.3}s",
        one.sim_duration_s,
        four.sim_duration_s
    );
    // and the e2e latency collapse is the user-visible version
    assert!(
        one.e2e_latency.mean() > four.e2e_latency.mean(),
        "sharding did not reduce e2e latency"
    );
}

#[test]
fn infinitely_fast_leader_ignores_service_model() {
    // service 0 must reproduce the classic engine even on the hot
    // scenario: no LeaderFree events, no backlog, identical numbers
    let run = |service: f64| {
        let mut cfg = hot_cfg(300);
        cfg.shard.leader_service_s = service;
        let widths = cfg.scheduler.widths.clone();
        sharded_engine(cfg, LeastLoadedRouter::new(widths, 16)).run()
    };
    let instant = run(0.0);
    let slow = run(0.0015);
    conserves(&instant, 300);
    conserves(&slow, 300);
    // a finite leader can only make things slower end to end
    assert!(slow.sim_duration_s >= instant.sim_duration_s);
    // and with an infinitely fast leader the FIFO never backlogs past
    // what a single event delivers
    assert!(instant.shard_stats.iter().all(|s| s.max_depth <= 64));
}
