//! Admission-gate equivalence and round-trip guarantees.
//!
//! The DRR gate is strictly additive: `--admission none` (the default)
//! must leave the engine's event stream and RNG consumption untouched —
//! the multi-tenant config fields exist, but with a single tenant no
//! tenant RNG stream is ever split and no gate event is ever scheduled.
//! With the gate on, a run is still a pure function of its seed, and a
//! recorded DRR run must replay to the same bytes: arrivals are traced
//! *before* admission, so shed requests shed identically on replay.

use slim_scheduler::config::{AdmissionKind, Config};
use slim_scheduler::coordinator::router::{AlgoRouter, RandomRouter};
use slim_scheduler::coordinator::{sharded_engine, RunOutcome};
use slim_scheduler::sim::scenarios;
use slim_scheduler::trace::{configure_for_replay, Trace, TraceRecorder};

fn base_cfg(seed: u64) -> Config {
    let mut cfg = Config::default();
    cfg.workload.total_requests = 400;
    cfg.workload.rate_hz = 250.0;
    cfg.seed = seed;
    cfg
}

fn run(cfg: &Config) -> RunOutcome {
    let router = RandomRouter::new(cfg.scheduler.widths.clone(), true, 8);
    sharded_engine(cfg.clone(), router).run()
}

/// Bit-level outcome equality on every reported metric.
fn assert_identical(a: &RunOutcome, b: &RunOutcome, ctx: &str) {
    assert_eq!(a.report.completed, b.report.completed, "{ctx}");
    assert_eq!(a.shed, b.shed, "{ctx}");
    assert_eq!(a.width_histogram, b.width_histogram, "{ctx}");
    assert_eq!(
        a.report.latency.mean().to_bits(),
        b.report.latency.mean().to_bits(),
        "{ctx}"
    );
    assert_eq!(
        a.e2e_latency.mean().to_bits(),
        b.e2e_latency.mean().to_bits(),
        "{ctx}"
    );
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits(), "{ctx}");
    assert_eq!(a.sim_duration_s.to_bits(), b.sim_duration_s.to_bits(), "{ctx}");
}

#[test]
fn admission_none_single_tenant_is_bit_identical_to_the_default_engine() {
    // spelling out the defaults (and touching tenant knobs that are
    // inert at tenants = 1) must not perturb a single draw, across the
    // leader-shard and parallel-planner matrix
    for leaders in [1usize, 3] {
        for plan_threads in [1usize, 2] {
            let mut plain = base_cfg(42);
            plain.shard.leaders = leaders;
            plain.shard.leader_service_s = 2e-4;
            plain.shard.plan_threads = plan_threads;
            let mut spelled = plain.clone();
            spelled.admission.kind = AdmissionKind::None;
            spelled.workload.tenants = 1;
            spelled.workload.tenant_zipf = 3.0; // meaningless without tenants
            let a = run(&plain);
            let b = run(&spelled);
            assert_eq!(a.report.completed, 400);
            assert_identical(
                &a,
                &b,
                &format!("leaders={leaders} plan_threads={plan_threads}"),
            );
        }
    }
}

#[test]
fn multi_tenant_without_a_gate_completes_everything() {
    let mut cfg = base_cfg(11);
    cfg.workload.tenants = 6;
    cfg.workload.tenant_zipf = 1.2;
    let out = run(&cfg);
    assert_eq!(out.report.completed, 400);
    assert_eq!(out.shed, 0);
    let arrived: u64 = out.tenant_stats.iter().map(|s| s.arrivals).sum();
    assert_eq!(arrived, 400);
    // Zipf popularity actually spreads traffic: several tenants see work
    let active = out.tenant_stats.iter().filter(|s| s.arrivals > 0).count();
    assert!(active >= 3, "only {active} tenants drew traffic");
    // the run is still a pure function of the seed
    assert_identical(&out, &run(&cfg), "tenants=6 admission=none");
}

#[test]
fn drr_record_replay_rerecord_is_byte_identical() {
    // the gate sheds mid-run, yet the trace must be a fixed point of
    // replaying itself: arrivals are recorded pre-admission, the gate
    // draws no RNG, and admission ticks fire at identical virtual times
    let mut cfg = Config::default();
    scenarios::apply_named("flash-crowd", &mut cfg).expect("registered scenario");
    cfg.workload.total_requests = 300;
    cfg.seed = 29;
    assert_eq!(cfg.admission.kind, AdmissionKind::Drr);

    let record = |cfg: &Config, arrivals: Option<&Trace>| -> (String, RunOutcome) {
        let router = AlgoRouter::by_name("edf", &cfg.scheduler.widths).unwrap();
        let recorder = TraceRecorder::new(cfg, "edf");
        let mut engine = sharded_engine(cfg.clone(), router);
        if let Some(trace) = arrivals {
            engine.set_arrivals(trace.arrivals().to_vec());
        }
        engine.set_trace_sink(Box::new(recorder.clone()));
        let out = engine.run();
        (recorder.to_jsonl(), out)
    };

    let (original, out) = record(&cfg, None);
    assert_eq!(out.report.completed + out.shed, 300);
    assert!(out.shed > 0, "the flash window must overflow the queue cap");

    let trace = Trace::parse(&original).expect("recorded trace parses");
    // every arrival is in the trace, shed ones included
    assert_eq!(trace.arrivals().len(), 300);

    let mut replay_cfg = cfg.clone();
    configure_for_replay(&mut replay_cfg, &trace);
    let (rerecorded, replay_out) = record(&replay_cfg, Some(&trace));
    assert_eq!(original, rerecorded, "DRR round trip diverged");
    assert_eq!(replay_out.shed, out.shed);
    assert_eq!(
        replay_out.jain_latency().to_bits(),
        out.jain_latency().to_bits()
    );
}
