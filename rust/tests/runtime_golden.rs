//! Cross-language golden test: the python-exported HLO artifacts,
//! executed through the rust PJRT runtime, must reproduce the jax
//! reference model's numbers bit-nearly. This is the contract that makes
//! the three-layer architecture trustworthy.

use slim_scheduler::runtime::artifact::artifacts_available;
use slim_scheduler::runtime::{HostTensor, SegmentExecutor};

fn read_bin(path: &std::path::Path, shape: &[usize]) -> HostTensor {
    let blob = std::fs::read(path).expect("golden file");
    let data: Vec<f32> = blob
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    HostTensor::from_vec(shape, data)
}

#[test]
fn every_golden_pair_matches() {
    if !artifacts_available("artifacts") {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut ex = SegmentExecutor::new("artifacts").expect("executor");
    let goldens = ex.index.goldens.clone();
    assert!(goldens.len() >= 4, "expected golden pairs in the manifest");
    let mut checked = 0;
    for g in &goldens {
        let x = read_bin(&ex.index.path_of(&g.input_file), &g.input_shape);
        let want = read_bin(&ex.index.path_of(&g.output_file), &g.output_shape);
        let got = ex
            .execute(g.segment, g.width, &x)
            .unwrap_or_else(|e| panic!("seg{} w{} b{}: {e:#}", g.segment, g.width, g.batch));
        assert_eq!(got.shape, want.shape);
        let diff = got.max_abs_diff(&want);
        assert!(
            diff < 2e-3,
            "seg{} w{} b{}: max abs diff {diff}",
            g.segment,
            g.width,
            g.batch
        );
        checked += 1;
    }
    println!("checked {checked} golden pairs");
}

#[test]
fn chained_segments_preserve_interface_invariants() {
    if !artifacts_available("artifacts") {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut ex = SegmentExecutor::new("artifacts").expect("executor");
    let meta = slim_scheduler::model::ModelMeta::default();
    let (in_shape, _) = meta.seg_io_shapes(0, 2);
    let x = HostTensor::from_vec(
        &in_shape,
        (0..in_shape.iter().product::<usize>())
            .map(|i| ((i % 23) as f32 - 11.0) / 11.0)
            .collect(),
    );
    // run a mixed chain and verify the zero-padding invariant between
    // every pair of segments (the w_prev-independence guarantee)
    let widths = [0.5, 0.25, 0.75, 0.5];
    let mut h = x;
    for seg in 0..3 {
        h = ex.execute(seg, widths[seg], &h).expect("segment");
        let c = *h.shape.last().unwrap();
        let c_act = slim_scheduler::model::c_active(
            meta.base_channels[seg],
            widths[seg],
        );
        for (i, &v) in h.data.iter().enumerate() {
            if i % c >= c_act {
                assert_eq!(v, 0.0, "seg{seg} leaked into padding at {i}");
            }
        }
        assert!(h.data.iter().any(|&v| v != 0.0), "seg{seg} produced zeros");
    }
    let logits = ex.execute(3, widths[3], &h).expect("head");
    assert_eq!(logits.shape, vec![2, meta.num_classes]);
    assert!(logits.data.iter().all(|v| v.is_finite()));
}
