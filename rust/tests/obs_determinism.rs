//! Observability byte-determinism tests.
//!
//! The metrics bundle (versioned JSON + Prometheus-style text) must be a
//! pure function of (seed, scenario, leaders): identical across reruns
//! and across `--plan-threads`, gated and gateless alike. The collector
//! draws no RNG and iterates no hash maps near output, so these tests
//! pin the whole export pipeline byte for byte. (Across *different*
//! `--leaders` values the per-shard columns legitimately differ — the
//! guarantee is per topology, matching the engine's own determinism.)

use slim_scheduler::config::Config;
use slim_scheduler::coordinator::router::RandomRouter;
use slim_scheduler::coordinator::{sharded_engine, RunOutcome};
use slim_scheduler::experiments;
use slim_scheduler::obs::{bundle_json, prometheus_text, BundleMeta};
use slim_scheduler::sim::scenarios;

fn run(cfg: &Config) -> RunOutcome {
    let router = RandomRouter::new(cfg.scheduler.widths.clone(), true, 8);
    sharded_engine(cfg.clone(), router).run()
}

/// Render the two export documents exactly as `repro simulate
/// --metrics-out` writes them.
fn bundle_bytes(out: &RunOutcome, cfg: &Config) -> (String, String) {
    let obs = out.obs.as_ref().expect("obs is on by default");
    let meta = BundleMeta {
        scenario: cfg.scenario.clone().unwrap_or_else(|| "paper".to_string()),
        seed: cfg.seed,
        requests: cfg.workload.total_requests,
        leaders: cfg.shard.leaders,
        router: "random".to_string(),
    };
    let mut json = bundle_json(obs, &meta).to_string_pretty();
    json.push('\n');
    (json, prometheus_text(obs, &meta))
}

#[test]
fn bundle_is_byte_identical_across_plan_threads_and_reruns() {
    for leaders in [1usize, 4] {
        let mk = |plan_threads: usize| {
            let mut cfg = experiments::paper_cluster_cfg(400, 42);
            cfg.shard.leaders = leaders;
            cfg.shard.leader_service_s = 2e-4;
            cfg.shard.plan_threads = plan_threads;
            let out = run(&cfg);
            bundle_bytes(&out, &cfg)
        };
        let (json1, prom1) = mk(1);
        let (json1b, prom1b) = mk(1);
        let (json4, prom4) = mk(4);
        assert_eq!(json1, json1b, "rerun drift at leaders={leaders}");
        assert_eq!(prom1, prom1b, "prom rerun drift at leaders={leaders}");
        assert_eq!(json1, json4, "plan-threads drift at leaders={leaders}");
        assert_eq!(prom1, prom4, "prom plan-threads drift at leaders={leaders}");
        assert!(json1.contains("\"metrics_version\""));
        assert!(prom1.starts_with("# slim_scheduler metrics"));
    }
}

#[test]
fn flash_crowd_drr_bundle_is_deterministic_and_gate_counters_surface() {
    let mk = || {
        let mut cfg = Config::default();
        scenarios::apply_named("flash-crowd", &mut cfg).unwrap();
        cfg.workload.total_requests = 400;
        cfg.seed = 7;
        let out = run(&cfg);
        let bytes = bundle_bytes(&out, &cfg);
        (out, bytes)
    };
    let (a, bytes_a) = mk();
    let (_b, bytes_b) = mk();
    assert_eq!(bytes_a.0, bytes_b.0, "gated bundle must be byte-stable");
    assert_eq!(bytes_a.1, bytes_b.1, "gated prom text must be byte-stable");

    // the 10x spike against a tight gate must actually exercise the
    // admission counters the bundle claims to export
    assert!(a.shed > 0, "flash-crowd sheds under the spike");
    assert!(a.degraded > 0, "flash-crowd degrades deep backlogs");
    let tenant_shed: u64 = a.tenant_stats.iter().map(|t| t.shed).sum();
    let tenant_deg: u64 = a.tenant_stats.iter().map(|t| t.degraded).sum();
    let tenant_forf: u64 = a.tenant_stats.iter().map(|t| t.credit_forfeits).sum();
    assert_eq!(tenant_shed, a.shed, "per-tenant shed sums to the total");
    assert_eq!(tenant_deg, a.degraded, "per-tenant degraded sums to the total");
    assert_eq!(
        tenant_forf, a.credit_forfeits,
        "per-tenant forfeits sum to the total"
    );
    let obs = a.obs.as_ref().unwrap();
    assert_eq!(
        obs.reg.counter_value("drr_shed_total"),
        Some(a.shed),
        "registry mirrors the gate's shed total"
    );
    // gate waits are real in a gated run: the stage histogram saw every
    // completion and at least some positive waits
    assert_eq!(obs.stages.global.gate_wait.count, a.report.completed);
    assert!(obs.stages.global.gate_wait.max > 0.0);
}

#[test]
fn stage_sums_telescope_to_e2e_without_dropout() {
    // per request: gate + leader + net + device == e2e exactly (the
    // stamps telescope); summed over all completions the identity holds
    // up to float addition order
    let mut cfg = experiments::paper_cluster_cfg(400, 42);
    cfg.shard.leaders = 2;
    cfg.shard.leader_service_s = 2e-4;
    let out = run(&cfg);
    let obs = out.obs.as_ref().unwrap();
    let st = &obs.stages.global;
    let n = out.report.completed;
    for h in st.hists() {
        assert_eq!(h.count, n, "every stage sees every completion");
    }
    let parts = st.gate_wait.sum + st.leader_wait.sum + st.net_wait.sum + st.device.sum;
    let e2e = st.e2e.sum;
    assert!(e2e > 0.0);
    let rel = (parts - e2e).abs() / e2e;
    assert!(rel < 1e-9, "stage decomposition drifted: {parts} vs {e2e} ({rel})");
    // ungated run: gate wait is identically zero → all-underflow histogram
    assert_eq!(st.gate_wait.underflow, n);

    // the per-tick series sampled the run on the telemetry clock
    let rows = obs.series.rows();
    assert!(!rows.is_empty(), "series must capture telemetry ticks");
    assert!(
        rows.windows(2).all(|w| w[0].t < w[1].t),
        "tick rows are time-ordered"
    );
    let last = rows.last().unwrap();
    assert_eq!(last.shard_depths.len(), 2, "one depth column per shard");
    assert_eq!(last.server_util.len(), cfg.devices.len());
    // events were counted: the total matches the sum of per-kind counters
    let total = obs.reg.counter_value("events_popped_total").unwrap();
    let per_kind: u64 = obs
        .reg
        .counters()
        .iter()
        .filter(|(name, _)| name.starts_with("events_popped{"))
        .map(|(_, v)| *v)
        .sum();
    assert!(total > 0);
    assert_eq!(total, per_kind, "per-kind event counters sum to the total");
}

#[test]
fn disabling_obs_leaves_the_simulation_bit_identical() {
    // the collector observes; it must never steer. An --obs false run
    // has to reproduce the default run's numbers exactly.
    let mk = |enabled: bool| {
        let mut cfg = experiments::paper_cluster_cfg(400, 42);
        cfg.shard.leaders = 2;
        cfg.obs.enabled = enabled;
        run(&cfg)
    };
    let on = mk(true);
    let off = mk(false);
    assert!(on.obs.is_some());
    assert!(off.obs.is_none());
    assert_eq!(on.report.completed, off.report.completed);
    assert_eq!(
        on.e2e_latency.mean().to_bits(),
        off.e2e_latency.mean().to_bits()
    );
    assert_eq!(on.total_energy_j.to_bits(), off.total_energy_j.to_bits());
    assert_eq!(on.sim_duration_s.to_bits(), off.sim_duration_s.to_bits());
}
