//! Plan-API equivalence suite.
//!
//! The PR 2 redesign replaced the per-head `Router::route(&snap, w_req,
//! seg, rng) -> Decision` with the windowed `Router::plan(&snap, heads,
//! rng) -> RoutingPlan`. The contract: with `route_window = 1` (the
//! default) every router must reproduce the pre-redesign decision stream
//! — and therefore every run metric — **bit-identically per seed**.
//!
//! These tests pin that contract against *legacy reference routers*:
//! verbatim re-implementations of the pre-plan per-head `route` bodies,
//! adapted to the new trait by planning exactly the first head. Running
//! the engine with a legacy reference and with the ported router under
//! the same seed must produce byte-equal outcomes. The PPO router's
//! scalar path is checked at the decision-stream level for both the
//! training (`Policy::sample`) and serving (`sample_notrain`) paths.

use slim_scheduler::config::Config;
use slim_scheduler::coordinator::router::{
    LeastLoadedRouter, RandomRouter, RoundRobinRouter,
};
use slim_scheduler::coordinator::{
    Decision, Engine, HeadView, Router, RoutingPlan, RunOutcome,
    TelemetrySnapshot,
};
use slim_scheduler::coordinator::telemetry::ServerTelemetry;
use slim_scheduler::ppo::policy::eps_at;
use slim_scheduler::ppo::PpoRouter;
use slim_scheduler::utilx::Rng;

// ---------------------------------------------------------------------
// Legacy per-head reference implementations (pre-plan `route` bodies)
// ---------------------------------------------------------------------

fn legacy_snap_width_up(widths: &[f64], w_req: f64) -> f64 {
    widths
        .iter()
        .cloned()
        .filter(|w| *w >= w_req - 1e-9)
        .fold(f64::INFINITY, f64::min)
        .min(widths.iter().cloned().fold(0.0, f64::max))
}

struct LegacyRandom {
    widths: Vec<f64>,
    randomize_width: bool,
    group: usize,
    next_tag: u64,
}

impl Router for LegacyRandom {
    fn name(&self) -> &'static str {
        "random"
    }
    fn plan(
        &mut self,
        snap: &TelemetrySnapshot,
        heads: &[HeadView],
        rng: &mut Rng,
    ) -> RoutingPlan {
        // the pre-redesign body, one head at a time (the engine at
        // route_window = 1 presents exactly one)
        let decisions = heads
            .iter()
            .map(|head| {
                let tag = self.next_tag;
                self.next_tag += 1;
                let width = if self.randomize_width {
                    *rng.choice(&self.widths)
                } else {
                    legacy_snap_width_up(&self.widths, head.w_req)
                };
                Decision {
                    server: rng.index(snap.servers.len().max(1)),
                    width,
                    group: self.group,
                    tag,
                }
            })
            .collect();
        RoutingPlan::new(decisions)
    }
}

struct LegacyRoundRobin {
    widths: Vec<f64>,
    group: usize,
    cursor: usize,
    next_tag: u64,
}

impl Router for LegacyRoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }
    fn plan(
        &mut self,
        snap: &TelemetrySnapshot,
        heads: &[HeadView],
        _rng: &mut Rng,
    ) -> RoutingPlan {
        let n = snap.servers.len().max(1);
        let decisions = heads
            .iter()
            .map(|head| {
                let server = self.cursor % n;
                self.cursor = (self.cursor + 1) % n;
                let tag = self.next_tag;
                self.next_tag += 1;
                Decision {
                    server,
                    width: legacy_snap_width_up(&self.widths, head.w_req),
                    group: self.group,
                    tag,
                }
            })
            .collect();
        RoutingPlan::new(decisions)
    }
}

struct LegacyLeastLoaded {
    widths: Vec<f64>,
    max_group: usize,
    next_tag: u64,
}

impl Router for LegacyLeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }
    fn plan(
        &mut self,
        snap: &TelemetrySnapshot,
        heads: &[HeadView],
        _rng: &mut Rng,
    ) -> RoutingPlan {
        // note: the legacy body used partial_cmp(..).unwrap(); scores are
        // finite here, where total_cmp orders identically
        let decisions = heads
            .iter()
            .map(|head| {
                let server = snap
                    .servers
                    .iter()
                    .enumerate()
                    .min_by(|(_, a), (_, b)| {
                        let sa = a.queue_len as f64 + a.util_pct / 25.0;
                        let sb = b.queue_len as f64 + b.util_pct / 25.0;
                        sa.partial_cmp(&sb).unwrap()
                    })
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                let group = if snap.fifo_len > 8 { self.max_group } else { 1 };
                let tag = self.next_tag;
                self.next_tag += 1;
                Decision {
                    server,
                    width: legacy_snap_width_up(&self.widths, head.w_req),
                    group,
                    tag,
                }
            })
            .collect();
        RoutingPlan::new(decisions)
    }
}

// ---------------------------------------------------------------------
// Engine-level bit-identity at route_window = 1
// ---------------------------------------------------------------------

fn small_cfg(seed: u64) -> Config {
    let mut cfg = Config::default();
    cfg.seed = seed;
    cfg.workload.total_requests = 400;
    cfg.workload.rate_hz = 250.0;
    assert_eq!(cfg.router.route_window, 1, "default must stay per-head");
    cfg
}

/// Byte-equality over every reported metric.
fn assert_bit_identical(a: &RunOutcome, b: &RunOutcome) {
    assert_eq!(a.report.completed, b.report.completed);
    assert_eq!(a.blocks_completed, b.blocks_completed);
    assert_eq!(a.width_histogram, b.width_histogram);
    assert_eq!(a.report.accuracy_pct.to_bits(), b.report.accuracy_pct.to_bits());
    assert_eq!(
        a.report.latency.mean().to_bits(),
        b.report.latency.mean().to_bits()
    );
    assert_eq!(
        a.report.energy.mean().to_bits(),
        b.report.energy.mean().to_bits()
    );
    assert_eq!(a.e2e_latency.mean().to_bits(), b.e2e_latency.mean().to_bits());
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    assert_eq!(a.sim_duration_s.to_bits(), b.sim_duration_s.to_bits());
}

#[test]
fn random_router_window1_matches_legacy_per_head_route() {
    for seed in [7u64, 42, 1234] {
        let cfg = small_cfg(seed);
        let widths = cfg.scheduler.widths.clone();
        let new = Engine::new(
            cfg.clone(),
            RandomRouter::new(widths.clone(), true, 8),
        )
        .run();
        let legacy = Engine::new(
            cfg,
            LegacyRandom {
                widths,
                randomize_width: true,
                group: 8,
                next_tag: 0,
            },
        )
        .run();
        assert_bit_identical(&new, &legacy);
    }
}

#[test]
fn round_robin_window1_matches_legacy_per_head_route() {
    let cfg = small_cfg(42);
    let widths = cfg.scheduler.widths.clone();
    let new =
        Engine::new(cfg.clone(), RoundRobinRouter::new(widths.clone(), 4)).run();
    let legacy = Engine::new(
        cfg,
        LegacyRoundRobin { widths, group: 4, cursor: 0, next_tag: 0 },
    )
    .run();
    assert_bit_identical(&new, &legacy);
}

#[test]
fn least_loaded_window1_matches_legacy_per_head_route() {
    let cfg = small_cfg(42);
    let widths = cfg.scheduler.widths.clone();
    let new =
        Engine::new(cfg.clone(), LeastLoadedRouter::new(widths.clone(), 16)).run();
    let legacy = Engine::new(
        cfg,
        LegacyLeastLoaded { widths, max_group: 16, next_tag: 0 },
    )
    .run();
    assert_bit_identical(&new, &legacy);
}

// ---------------------------------------------------------------------
// PPO scalar-path equivalence (decision streams)
// ---------------------------------------------------------------------

fn probe_snap(n: usize, fifo_len: usize) -> TelemetrySnapshot {
    TelemetrySnapshot {
        fifo_len,
        done_count: 25,
        total_requests: 400,
        servers: (0..n)
            .map(|i| ServerTelemetry {
                queue_len: 2 * i,
                power_w: 110.0 + 5.0 * i as f64,
                util_pct: 22.0 * i as f64,
                mem_util: 0.3,
                instances: 1,
            })
            .collect(),
    }
}

const W: [f64; 4] = [0.25, 0.5, 0.75, 1.0];

#[test]
fn ppo_training_plan_window1_matches_legacy_sample_path() {
    // the legacy body: state = snapshot vector, ε from the schedule at
    // the pre-increment step, one Policy::sample draw, widths/groups
    // indexed by the action
    let cfg = slim_scheduler::config::PpoCfg::default();
    let mut router = PpoRouter::new(3, W.to_vec(), cfg.clone(), 9);
    let twin = PpoRouter::new(3, W.to_vec(), cfg.clone(), 9);
    let mut rng_a = Rng::new(31);
    let mut rng_b = rng_a.clone();
    let mut step = 0u64;
    let mut next_tag = 0u64;
    for i in 0..150usize {
        let snap = probe_snap(3, 4 + i % 9);
        let head = HeadView::new(W[i % 4], i % 4);
        let got = router.route_one(&snap, &head, &mut rng_a);

        let state = snap.to_state_vector();
        let eps = eps_at(step, cfg.eps_max, cfg.eps_min, cfg.t_dec);
        step += 1;
        let tag = next_tag;
        next_tag += 1;
        let (action, _eval) = twin.policy.sample(&state, eps, &mut rng_b);
        let want = Decision {
            server: action.srv.min(snap.servers.len().saturating_sub(1)),
            width: W[action.w.min(W.len() - 1)],
            group: cfg.groups[action.g.min(cfg.groups.len() - 1)],
            tag,
        };
        assert_eq!(got, want, "step {i}");
    }
}

#[test]
fn ppo_eval_plan_window1_matches_legacy_notrain_path() {
    let cfg = slim_scheduler::config::PpoCfg::default();
    let mut router = PpoRouter::new(3, W.to_vec(), cfg.clone(), 9);
    router.eval_mode();
    let twin = PpoRouter::new(3, W.to_vec(), cfg.clone(), 9);
    let mut rng_a = Rng::new(32);
    let mut rng_b = rng_a.clone();
    let mut scratch = (Vec::new(), Vec::new());
    let mut next_tag = 0u64;
    for i in 0..150usize {
        let snap = probe_snap(3, 2 + i % 13);
        let head = HeadView::new(W[i % 4], i % 4);
        let got = router.route_one(&snap, &head, &mut rng_a);

        let state = snap.to_state_vector();
        let tag = next_tag;
        next_tag += 1;
        let action =
            twin.policy.sample_notrain(&state, 0.0, &mut rng_b, &mut scratch);
        let want = Decision {
            server: action.srv.min(snap.servers.len().saturating_sub(1)),
            width: W[action.w.min(W.len() - 1)],
            group: cfg.groups[action.g.min(cfg.groups.len() - 1)],
            tag,
        };
        assert_eq!(got, want, "step {i}");
    }
}

// ---------------------------------------------------------------------
// Windowed plans stay valid and complete
// ---------------------------------------------------------------------

#[test]
fn every_router_completes_under_a_wide_window() {
    for window in [4usize, 16] {
        let mut cfg = small_cfg(42);
        cfg.router.route_window = window;
        let widths = cfg.scheduler.widths.clone();

        let out = Engine::new(
            cfg.clone(),
            RandomRouter::new(widths.clone(), true, 8),
        )
        .run();
        assert_eq!(out.report.completed, 400, "random w={window}");

        let out =
            Engine::new(cfg.clone(), RoundRobinRouter::new(widths.clone(), 4))
                .run();
        assert_eq!(out.report.completed, 400, "rr w={window}");

        let out =
            Engine::new(cfg.clone(), LeastLoadedRouter::new(widths.clone(), 16))
                .run();
        assert_eq!(out.report.completed, 400, "ll w={window}");

        let mut ppo = PpoRouter::new(
            cfg.devices.len(),
            widths.clone(),
            cfg.ppo.clone(),
            cfg.seed,
        );
        ppo.eval_mode();
        let out = Engine::new(cfg, ppo).run();
        assert_eq!(out.report.completed, 400, "ppo w={window}");
    }
}
