//! Control-plane equivalence and determinism guarantees.
//!
//! The adaptive control plane is strictly additive: `--controller none`
//! (the default) builds no controller object, so the engine's knob
//! state is pinned to the config and a run — trace bytes included — is
//! identical to the pre-control-plane engine's, across the leader-shard
//! and parallel-planner matrix. With `--controller backlog` a run is
//! still a pure function of its seed (the controller sees only the
//! sim-clock tick row), knob changes land in the trace as `knobs`
//! events, and a recorded run replays to the same bytes: the replay
//! engine re-derives the same tick rows and retunes on the same ticks.

use slim_scheduler::config::{AdmissionKind, Config, ControllerKind};
use slim_scheduler::coordinator::router::AlgoRouter;
use slim_scheduler::coordinator::{sharded_engine, RunOutcome};
use slim_scheduler::sim::scenarios;
use slim_scheduler::trace::{configure_for_replay, Trace, TraceRecorder};

/// Flash-crowd with the per-tenant queue cap raised so gate pressure
/// can actually cross the backlog controller's high-water mark (the
/// stock cap of 16 pins pressure below it).
fn flash_cfg(seed: u64) -> Config {
    let mut cfg = Config::default();
    scenarios::apply_named("flash-crowd", &mut cfg).expect("registered scenario");
    cfg.workload.total_requests = 400;
    cfg.seed = seed;
    cfg.admission.queue_cap = 64;
    assert_eq!(cfg.admission.kind, AdmissionKind::Drr);
    cfg
}

fn record(cfg: &Config, arrivals: Option<&Trace>) -> (String, RunOutcome) {
    let router = AlgoRouter::by_name("edf", &cfg.scheduler.widths).unwrap();
    let recorder = TraceRecorder::new(cfg, "edf");
    let mut engine = sharded_engine(cfg.clone(), router);
    if let Some(trace) = arrivals {
        engine.set_arrivals(trace.arrivals().to_vec());
    }
    engine.set_trace_sink(Box::new(recorder.clone()));
    let out = engine.run();
    (recorder.to_jsonl(), out)
}

fn knobs_lines(trace: &str) -> usize {
    trace.lines().filter(|l| l.contains("\"ev\":\"knobs\"")).count()
}

/// Bit-level outcome equality on every reported metric.
fn assert_identical(a: &RunOutcome, b: &RunOutcome, ctx: &str) {
    assert_eq!(a.report.completed, b.report.completed, "{ctx}");
    assert_eq!(a.shed, b.shed, "{ctx}");
    assert_eq!(a.width_histogram, b.width_histogram, "{ctx}");
    assert_eq!(
        a.report.latency.mean().to_bits(),
        b.report.latency.mean().to_bits(),
        "{ctx}"
    );
    assert_eq!(
        a.e2e_latency.mean().to_bits(),
        b.e2e_latency.mean().to_bits(),
        "{ctx}"
    );
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits(), "{ctx}");
    assert_eq!(a.sim_duration_s.to_bits(), b.sim_duration_s.to_bits(), "{ctx}");
}

#[test]
fn controller_none_matches_the_default_engine_across_the_shard_matrix() {
    // spelling --controller none must not perturb a single draw or
    // grow the trace by a single byte, at any (leaders, plan_threads)
    for leaders in [1usize, 4] {
        for plan_threads in [1usize, 4] {
            let mut plain = flash_cfg(7);
            plain.shard.leaders = leaders;
            plain.shard.plan_threads = plan_threads;
            let mut spelled = plain.clone();
            spelled.ctrl.controller = ControllerKind::None;
            let (trace_a, a) = record(&plain, None);
            let (trace_b, b) = record(&spelled, None);
            assert_eq!(a.report.completed + a.shed, 400);
            assert_eq!(
                trace_a, trace_b,
                "leaders={leaders} plan_threads={plan_threads}"
            );
            assert_eq!(knobs_lines(&trace_a), 0, "controller-less trace is knob-free");
            assert_identical(
                &a,
                &b,
                &format!("leaders={leaders} plan_threads={plan_threads}"),
            );
        }
    }
}

#[test]
fn backlog_runs_are_pure_functions_of_the_seed_across_plan_threads() {
    // the controller consumes the sim-clock tick row only, so a tuned
    // run keeps the engine's determinism contract: byte-identical
    // repeats, invariant across the parallel planner's thread count
    let mut reference: Option<String> = None;
    for plan_threads in [1usize, 2, 4] {
        let mut cfg = flash_cfg(29);
        cfg.ctrl.controller = ControllerKind::Backlog;
        cfg.shard.leaders = 4;
        cfg.shard.plan_threads = plan_threads;
        let (trace, out) = record(&cfg, None);
        assert_eq!(out.report.completed + out.shed, 400);
        assert!(
            knobs_lines(&trace) >= 2,
            "expected the initial state plus at least one retune \
             (plan_threads={plan_threads}), got {}",
            knobs_lines(&trace)
        );
        match &reference {
            None => reference = Some(trace),
            Some(r) => assert_eq!(r, &trace, "plan_threads={plan_threads}"),
        }
    }
}

#[test]
fn backlog_record_replay_rerecord_is_byte_identical() {
    // a tuned run must be a fixed point of replaying itself: arrivals
    // are recorded pre-admission, and the replay engine re-derives the
    // same tick rows, so it retunes on the same ticks to the same knobs
    let mut cfg = flash_cfg(29);
    cfg.ctrl.controller = ControllerKind::Backlog;

    let (original, out) = record(&cfg, None);
    assert_eq!(out.report.completed + out.shed, 400);
    assert!(out.shed > 0, "the flash window must overflow the queue cap");
    assert!(knobs_lines(&original) >= 2, "relief never engaged");

    let trace = Trace::parse(&original).expect("recorded trace parses");
    assert_eq!(trace.arrivals().len(), 400, "shed arrivals stay in the trace");

    let mut replay_cfg = cfg.clone();
    configure_for_replay(&mut replay_cfg, &trace);
    let (rerecorded, replay_out) = record(&replay_cfg, Some(&trace));
    assert_eq!(original, rerecorded, "tuned round trip diverged");
    assert_eq!(replay_out.shed, out.shed);
    assert_eq!(
        replay_out.jain_latency().to_bits(),
        out.jain_latency().to_bits()
    );
}

#[test]
fn backlog_relief_actually_changes_the_run() {
    // guard against the controller being a silent no-op: under the
    // flash the relief tuple (doubled quantum, halved queue cap) must
    // steer admission away from the untuned run — even with the knobs
    // events stripped, the traces differ
    let base = flash_cfg(29);
    let mut tuned = base.clone();
    tuned.ctrl.controller = ControllerKind::Backlog;
    let (trace_none, _) = record(&base, None);
    let (trace_backlog, _) = record(&tuned, None);
    let strip = |t: &str| {
        t.lines()
            .filter(|l| !l.contains("\"ev\":\"knobs\""))
            .collect::<Vec<_>>()
            .join("\n")
    };
    assert_ne!(
        strip(&trace_none),
        strip(&trace_backlog),
        "backlog relief engaged but left the run untouched"
    );
}
