//! Trace round-trip determinism — the pinned invariant of the trace
//! subsystem: **record → replay → re-record is the identity** on the
//! trace bytes.
//!
//! A recorded run's trace embeds its arrivals; replaying those arrivals
//! through an identically configured engine (same router, same seed)
//! must walk the exact same event sequence, so re-recording the replay
//! reproduces the original JSONL byte for byte — across seeds, leader
//! counts, shard assignments, and (for PPO) `--workers` training
//! settings. If any engine change breaks this, trace-driven evaluation
//! (and the counterfactual A/B harness built on it) silently measures
//! the wrong thing; these tests make that loud.

use slim_scheduler::config::{Config, ShardAssignKind};
use slim_scheduler::coordinator::router::AlgoRouter;
use slim_scheduler::coordinator::sharded_engine;
use slim_scheduler::experiments;
use slim_scheduler::ppo::run_ppo_episode_io;
use slim_scheduler::trace::{
    compare_routers, configure_for_replay, StreamingTraceWriter, Trace,
    TraceRecorder,
};
use slim_scheduler::utilx::Json;

fn small_cfg(seed: u64) -> Config {
    let mut cfg = Config::default();
    cfg.workload.total_requests = 300;
    cfg.workload.rate_hz = 250.0;
    cfg.seed = seed;
    cfg
}

/// Record one run of `router_name` under `cfg` and return the JSONL.
fn record(cfg: &Config, router_name: &str) -> String {
    let router = AlgoRouter::by_name(router_name, &cfg.scheduler.widths)
        .unwrap_or_else(|| panic!("unknown router {router_name}"));
    let recorder = TraceRecorder::new(cfg, router_name);
    let mut engine = sharded_engine(cfg.clone(), router);
    engine.set_trace_sink(Box::new(recorder.clone()));
    let out = engine.run();
    assert_eq!(out.report.completed, cfg.workload.total_requests as u64);
    recorder.to_jsonl()
}

/// Replay `trace` under `cfg` with `router_name`, re-recording it.
fn replay_and_rerecord(cfg: &Config, trace: &Trace, router_name: &str) -> String {
    let router = AlgoRouter::by_name(router_name, &cfg.scheduler.widths).unwrap();
    let mut cfg = cfg.clone();
    configure_for_replay(&mut cfg, trace);
    let recorder = TraceRecorder::new(&cfg, router_name);
    let mut engine = sharded_engine(cfg, router);
    engine.set_arrivals(trace.arrivals_arena());
    engine.set_trace_sink(Box::new(recorder.clone()));
    engine.run();
    recorder.to_jsonl()
}

#[test]
fn record_replay_rerecord_is_byte_identical_across_seeds_and_leaders() {
    for seed in [11u64, 29] {
        for leaders in [1usize, 3] {
            for plan_threads in [1usize, 2] {
                let mut cfg = small_cfg(seed);
                cfg.shard.leaders = leaders;
                cfg.shard.leader_service_s = 2e-4;
                cfg.shard.plan_threads = plan_threads;
                let original = record(&cfg, "random");
                let trace =
                    Trace::parse(&original).expect("recorded trace parses");
                let rerecorded = replay_and_rerecord(&cfg, &trace, "random");
                assert_eq!(
                    original, rerecorded,
                    "round trip diverged (seed {seed}, leaders {leaders}, \
                     plan_threads {plan_threads})"
                );
            }
        }
    }
}

#[test]
fn streaming_writer_records_a_real_run_byte_identically() {
    // the CLI records through StreamingTraceWriter (constant memory);
    // its on-disk bytes must equal the in-memory recorder's JSONL for
    // the same engine run, and the streaming loader must recover the
    // same arrival stream
    let mut cfg = small_cfg(23);
    cfg.shard.leaders = 2;
    let in_memory = record(&cfg, "random");

    let path = std::env::temp_dir().join(format!(
        "slim_stream_roundtrip_{}.jsonl",
        std::process::id()
    ));
    let path_s = path.to_str().unwrap().to_string();
    let writer = StreamingTraceWriter::create(&path_s, &cfg, "random")
        .expect("create stream");
    let router = AlgoRouter::by_name("random", &cfg.scheduler.widths).unwrap();
    let mut engine = sharded_engine(cfg.clone(), router);
    engine.set_trace_sink(Box::new(writer.clone()));
    engine.run();
    let n = writer.finish().expect("flush stream");
    assert!(n > 0);

    let streamed = std::fs::read_to_string(&path).expect("read stream");
    assert_eq!(in_memory, streamed, "streaming writer diverged from recorder");

    let loaded = Trace::load_streaming(&path_s).expect("streaming load");
    let parsed = Trace::parse(&in_memory).unwrap();
    assert_eq!(loaded.arrivals().len(), parsed.arrivals().len());
    assert_eq!(loaded.config().map(|c| c.seed), parsed.config().map(|c| c.seed));
    std::fs::remove_file(&path).ok();
}

#[test]
fn round_trip_holds_for_edf_with_key_affine_sharding() {
    let mut cfg = small_cfg(7);
    cfg.shard.leaders = 2;
    cfg.shard.assign = ShardAssignKind::KeyAffine;
    cfg.router.route_window = 4;
    cfg.router.sla_s = 0.4;
    let original = record(&cfg, "edf");
    let trace = Trace::parse(&original).unwrap();
    assert_eq!(trace.arrivals().len(), 300);
    let rerecorded = replay_and_rerecord(&cfg, &trace, "edf");
    assert_eq!(original, rerecorded);
}

#[test]
fn round_trip_holds_for_ppo_across_worker_counts() {
    // a PPO policy trained per (seed, workers) is deterministic, so an
    // eval-mode recording of it must round-trip like any algorithmic
    // router — for the sequential (workers=1) and parallel (workers=2)
    // trainers alike
    for workers in [1usize, 2] {
        let mut cfg = small_cfg(5);
        cfg.workload.total_requests = 250;
        cfg.ppo.horizon = 64;
        let train = |cfg: &Config| {
            let mut r = experiments::train_ppo_workers(
                cfg,
                cfg.ppo.reward,
                workers, // episodes = workers keeps the test fast
                workers,
            );
            r.eval_mode();
            r
        };

        let recorder = TraceRecorder::new(&cfg, "ppo");
        let (out, _) = run_ppo_episode_io(
            &cfg,
            train(&cfg),
            None,
            Some(Box::new(recorder.clone())),
        );
        assert_eq!(out.report.completed, 250);
        let original = recorder.to_jsonl();
        let trace = Trace::parse(&original).unwrap();

        let mut replay_cfg = cfg.clone();
        configure_for_replay(&mut replay_cfg, &trace);
        let recorder2 = TraceRecorder::new(&replay_cfg, "ppo");
        run_ppo_episode_io(
            &replay_cfg,
            train(&cfg),
            Some(trace.arrivals_arena()),
            Some(Box::new(recorder2.clone())),
        );
        assert_eq!(
            original,
            recorder2.to_jsonl(),
            "ppo round trip diverged (workers {workers})"
        );
    }
}

#[test]
fn header_reconstructed_config_reproduces_the_run() {
    // the replay CLI path: rebuild the config from the trace header
    // (Config::from_json of the embedded document) instead of carrying
    // the original object — the tail must still match byte for byte
    let mut cfg = small_cfg(13);
    cfg.router.sla_s = 0.5;
    cfg.router.route_window = 2;
    let original = record(&cfg, "least-loaded");
    let trace = Trace::parse(&original).unwrap();
    let from_header = trace.config().expect("recorded trace embeds its config");
    assert_eq!(from_header.seed, 13);
    assert_eq!(from_header.router.sla_s, 0.5);
    assert_eq!(from_header.router.route_window, 2);
    let rerecorded = replay_and_rerecord(&from_header, &trace, "least-loaded");
    assert_eq!(original, rerecorded);
}

#[test]
fn different_seeds_byte_diff() {
    let a = record(&small_cfg(1), "random");
    let b = record(&small_cfg(2), "random");
    assert_ne!(a, b);
    // and both parse into the same arrival count
    assert_eq!(Trace::parse(&a).unwrap().arrivals().len(), 300);
    assert_eq!(Trace::parse(&b).unwrap().arrivals().len(), 300);
}

#[test]
fn malformed_and_truncated_traces_error_cleanly() {
    let original = record(&small_cfg(3), "random");

    // cut mid-line: the final partial record is invalid JSON
    let cut = &original[..original.len() - 30];
    let e = Trace::parse(cut).unwrap_err();
    assert!(e.line > 1, "{e}");

    // drop arrival records wholesale: the header's declared request
    // count no longer matches
    let gutted: String = original
        .lines()
        .filter(|l| !l.contains("\"ev\":\"arrival\"") || l.contains("\"id\":0,"))
        .map(|l| format!("{l}\n"))
        .collect();
    let e = Trace::parse(&gutted).unwrap_err();
    assert!(e.msg.contains("truncated"), "{e}");

    // garbage document
    assert!(Trace::parse("not json at all\n").is_err());
}

#[test]
fn compare_over_a_recorded_trace_emits_paired_deltas() {
    // the acceptance-criteria path end to end: record once, A/B two
    // routers over the same arrivals, check the paired summary keys
    let cfg = small_cfg(17);
    let original = record(&cfg, "random");
    let trace = Trace::parse(&original).unwrap();
    let names: Vec<String> = ["random", "edf"].iter().map(|s| s.to_string()).collect();
    let report = compare_routers(&cfg, &trace, &names).unwrap();
    let rendered = report.to_string_pretty();
    assert!(rendered.contains("latency_delta_mean_s"));
    let pairs = report.get("pairs").and_then(Json::as_arr).unwrap();
    assert_eq!(pairs.len(), 1);
    assert_eq!(pairs[0].get("n_pairs").and_then(Json::as_usize), Some(300));
    assert_eq!(
        pairs[0]
            .get("per_request")
            .and_then(Json::as_arr)
            .map(<[Json]>::len),
        Some(300)
    );
}
