//! Mini end-to-end integration: a PPO router (trained briefly in the
//! simulator) drives REAL PJRT CPU inference through the full segment
//! chain — the same composition `examples/serve_cluster.rs` demonstrates
//! at larger scale, asserted here as part of `cargo test`.

use slim_scheduler::config::{Config, RewardCfg};
use slim_scheduler::coordinator::router::Router;
use slim_scheduler::coordinator::telemetry::{ServerTelemetry, TelemetrySnapshot};
use slim_scheduler::experiments;
use slim_scheduler::model::{AccuracyPrior, ModelMeta, NUM_SEGMENTS};
use slim_scheduler::runtime::artifact::artifacts_available;
use slim_scheduler::runtime::{HostTensor, SegmentExecutor};
use slim_scheduler::utilx::Rng;

#[test]
#[cfg_attr(debug_assertions, ignore = "slow without --release")]
fn ppo_routed_real_inference_end_to_end() {
    if !artifacts_available("artifacts") {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // 1. train a router in the simulator (tiny budget)
    let mut sim_cfg = Config::default();
    sim_cfg.workload.total_requests = 800;
    let mut router = experiments::train_ppo(&sim_cfg, RewardCfg::balanced(), 2);
    router.eval_mode();

    // 2. serve 12 images for real
    let meta = ModelMeta::default();
    let prior = AccuracyPrior::new();
    let mut ex = SegmentExecutor::new("artifacts").expect("executor");
    let mut rng = Rng::new(5);
    let (in_shape, _) = meta.seg_io_shapes(0, 1);

    let snap = TelemetrySnapshot {
        fifo_len: 12,
        done_count: 0,
        total_requests: 12,
        servers: (0..3)
            .map(|_| ServerTelemetry::default())
            .collect(),
    };

    let mut acc_sum = 0.0;
    for i in 0..12u64 {
        let mut x = HostTensor::zeros(&in_shape);
        for v in &mut x.data {
            *v = rng.normal() as f32 * 0.5;
        }
        let mut widths = [0.0; NUM_SEGMENTS];
        let mut h = x;
        for seg in 0..NUM_SEGMENTS {
            let head = slim_scheduler::coordinator::HeadView::new(0.5, seg);
            let d = router.route_one(&snap, &head, &mut rng);
            assert!(d.server < 3);
            widths[seg] = d.width;
            h = ex.execute(seg, d.width, &h).expect("segment execution");
        }
        assert_eq!(h.shape, vec![1, meta.num_classes], "request {i}");
        assert!(h.data.iter().all(|v| v.is_finite()));
        acc_sum += prior.lookup(&widths);
    }
    let mean_acc = acc_sum / 12.0;
    assert!(
        (70.0..=76.5).contains(&mean_acc),
        "served accuracy prior out of range: {mean_acc}"
    );
    assert!(ex.executions >= 48);
}
