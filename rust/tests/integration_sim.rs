//! Cross-module integration tests over the simulated cluster: router ×
//! engine × greedy × device invariants, cost-model agreement with the
//! python-exported manifest, and failure injection.

use slim_scheduler::config::{Config, RewardCfg};
use slim_scheduler::coordinator::router::{
    LeastLoadedRouter, RandomRouter, RoundRobinRouter,
};
use slim_scheduler::coordinator::Engine;
use slim_scheduler::experiments;
use slim_scheduler::model::ModelMeta;
use slim_scheduler::utilx::Json;

fn cfg(requests: usize, rate: f64) -> Config {
    let mut c = Config::default();
    c.workload.total_requests = requests;
    c.workload.rate_hz = rate;
    c
}

#[test]
fn all_routers_complete_and_conserve_requests() {
    for name in ["random", "rr", "ll"] {
        let c = cfg(400, 250.0);
        let widths = c.scheduler.widths.clone();
        let out = match name {
            "random" => Engine::new(c, RandomRouter::new(widths, true, 8)).run(),
            "rr" => Engine::new(c, RoundRobinRouter::new(widths, 8)).run(),
            _ => Engine::new(c, LeastLoadedRouter::new(widths, 16)).run(),
        };
        assert_eq!(out.report.completed, 400, "{name}");
        assert_eq!(out.width_execs(), 4 * 400, "{name}");
        assert!(out.report.latency.count() > 0, "{name}");
        assert!(out.total_energy_j > 0.0, "{name}");
    }
}

#[test]
fn rust_cost_model_matches_python_manifest() {
    // The manifest's flops table is produced by python/compile/model.py;
    // ModelMeta::seg_flops must agree exactly on the whole exported grid.
    let text = match std::fs::read_to_string("artifacts/manifest.json") {
        Ok(t) => t,
        Err(_) => {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
    let json = Json::parse(&text).expect("manifest parses");
    let meta = ModelMeta::default();
    let flops = json.get("flops").expect("flops table");
    let map = flops.as_map().expect("flops is an object");
    assert!(map.len() >= 100, "expected a dense flops grid");
    for (key, value) in map {
        let parts: Vec<&str> = key.split('|').collect();
        let seg: usize = parts[0].parse().unwrap();
        let w: f64 = parts[1].parse().unwrap();
        let wp: f64 = parts[2].parse().unwrap();
        let b: usize = parts[3].parse().unwrap();
        let want = value.as_f64().unwrap() as u64;
        let got = meta.seg_flops(seg, w, wp, b);
        assert_eq!(got, want, "flops mismatch at {key}");
    }

    // weight bytes as well
    let seg_bytes = json
        .get("segment_weight_bytes")
        .and_then(Json::as_usize_vec)
        .expect("segment_weight_bytes");
    for (s, &want) in seg_bytes.iter().enumerate() {
        assert_eq!(meta.seg_weight_bytes(s) as usize, want, "seg{s} weight bytes");
    }
}

#[test]
fn vram_starved_cluster_still_completes() {
    // failure injection: shrink the VRAM budget to a single instance's
    // worth — scale-ups mostly fail, requeues spike, but nothing is lost.
    let mut c = cfg(150, 100.0);
    c.scheduler.m_max_bytes = 40 << 20; // 40 MB budget
    let widths = c.scheduler.widths.clone();
    let out = Engine::new(c, RandomRouter::new(widths, false, 4)).run();
    assert_eq!(out.report.completed, 150);
    let blocked: u64 = out.greedy_stats.iter().map(|s| s.blocked_by_vram).sum();
    assert!(blocked > 0, "expected VRAM pressure, got none");
}

#[test]
fn unloader_reclaims_memory_over_a_long_tail() {
    let mut c = cfg(300, 400.0);
    c.scheduler.t_idle_s = 0.5;
    let widths = c.scheduler.widths.clone();
    let out = Engine::new(c, RandomRouter::new(widths, true, 4)).run();
    let unloads: u64 = out.greedy_stats.iter().map(|s| s.unloads).sum();
    assert!(unloads > 0, "idle unloader never fired");
}

#[test]
fn ppo_learns_better_than_random_under_heavy_penalty() {
    let c = cfg(1500, 140.0);
    let baseline = experiments::run_random_baseline(&c);
    let (ppo, router) = experiments::run_ppo_experiment(&c, RewardCfg::overfit(), 5);
    assert!(router.stats.updates > 0);
    assert!(
        ppo.report.latency.mean() < baseline.report.latency.mean() * 0.5,
        "ppo {} vs baseline {}",
        ppo.report.latency.mean(),
        baseline.report.latency.mean()
    );
}

#[test]
fn telemetry_variance_tracks_imbalance() {
    // round-robin spreads load evenly; a single-server hammer maximizes
    // imbalance. GPU-var telemetry must reflect that ordering.
    let c = cfg(500, 300.0);
    let widths = c.scheduler.widths.clone();
    let rr = Engine::new(c.clone(), RoundRobinRouter::new(widths.clone(), 8)).run();

    struct PinRouter(slim_scheduler::coordinator::router::RoundRobinRouter);
    impl slim_scheduler::coordinator::Router for PinRouter {
        fn name(&self) -> &'static str {
            "pin"
        }
        fn plan(
            &mut self,
            snap: &slim_scheduler::coordinator::TelemetrySnapshot,
            heads: &[slim_scheduler::coordinator::HeadView],
            rng: &mut slim_scheduler::utilx::Rng,
        ) -> slim_scheduler::coordinator::RoutingPlan {
            let mut decisions = self.0.plan(snap, heads, rng).into_decisions();
            for d in &mut decisions {
                d.server = 0; // hammer one server
            }
            slim_scheduler::coordinator::RoutingPlan::new(decisions)
        }
    }
    let pinned = Engine::new(
        c,
        PinRouter(RoundRobinRouter::new(widths, 8)),
    )
    .run();
    assert!(
        pinned.telemetry.util_variance.mean() > rr.telemetry.util_variance.mean(),
        "pinned {} !> rr {}",
        pinned.telemetry.util_variance.mean(),
        rr.telemetry.util_variance.mean()
    );
}

#[test]
fn burst_factor_worsens_tail_latency() {
    // base rate below cluster capacity so the calm run never saturates;
    // the bursty run hits 6x spikes that pile up queues
    let mut calm = cfg(1000, 55.0);
    calm.workload.burst_factor = 1.0;
    let mut bursty = cfg(1000, 55.0);
    bursty.workload.burst_factor = 6.0;
    let w = calm.scheduler.widths.clone();
    let out_calm = Engine::new(calm, RandomRouter::new(w.clone(), true, 8)).run();
    let out_burst = Engine::new(bursty, RandomRouter::new(w, true, 8)).run();
    assert!(
        out_burst.e2e_latency.percentile(99.0) > out_calm.e2e_latency.percentile(99.0),
        "burst p99 {} !> calm p99 {}",
        out_burst.e2e_latency.percentile(99.0),
        out_calm.e2e_latency.percentile(99.0)
    );
}
